//! Model persistence: save / load a trained LTLS model (weights + trellis
//! + label↔path assignment) as a single self-describing binary file, so
//! `ltls train` can hand a model to `ltls serve` / `ltls eval` across
//! processes — plus the epoch-boundary training **checkpoint** format used
//! by [`crate::train::ParallelTrainer`] for crash-safe resume.
//!
//! Model format **v3** (little-endian):
//! ```text
//! magic "LTLS" | version u32 | C u64 | width u32 | D u64 | E u64 | n_labels u64
//! backend u32 | meta_len u64 | meta[meta_len]
//! bias  [E f32]
//! n_pairs u64 | (label u32, path u64) * n_pairs
//! wlen u64 | zero padding to the next 64-byte file offset
//! weights [wlen bytes]                                         (EOF)
//! ```
//!
//! * `backend` tags the weight representation ([`Backend`]): dense (0),
//!   hashed (1) or q8 (2). `meta` is the store-specific fixed section —
//!   empty for dense, `(bits u32, seed u64)` for hashed, `E` f32 scales
//!   for q8.
//! * The weight block is the **last** section and starts at a 64-byte file
//!   offset, so a page-aligned `mmap` of the file yields an aligned,
//!   zero-copy `&[f32]`/`&[i8]` view: [`load_any_mmap`] /
//!   [`deserialize_mapped`] parse only the small sections onto the heap
//!   and borrow the weights from the mapping ([`crate::model::mmap`]).
//!
//! Format **v4** is a v3 file carrying a label-space **shard slice**
//! ([`crate::model::shard::ShardStore`], written by [`serialize_shard`] /
//! the `ltls shard` subcommand). It inserts, between the backend tag and
//! `meta_len`:
//! ```text
//! n_shards u32 | shard_id u32 | n_owned u64 | owned[u32 × n_owned]
//! ```
//! `E` stays the **full** model's edge count; `bias` has `n_owned`
//! entries, `meta` and `weights` are the sliced inner store's sections
//! (the owned columns only), and the pairs table is the full label↔path
//! table. The owned-edge list lives in the file, so a slice is
//! self-describing — loading never recomputes the shard plan. Regular
//! saves keep writing v3.
//!
//! Version history: v1 had no width field (loads as width 2); v2 added
//! `width u32` and stored `bias | weights | pairs` with no backend
//! framing. Both load as **dense** through the current reader. The loader
//! is generic over [`Topology`] and the [`WeightStore`] —
//! `deserialize::<Trellis, DenseStore>` rejects wide or non-dense files —
//! and [`load_any`] dispatches on the stored (width, backend, shard)
//! triple for callers (the CLI) that learn all of it from the file.
//!
//! Checkpoint format (little-endian, versioned independently):
//! ```text
//! magic "LTCK" | version u32 | epoch u32 | step u64 | seed u64
//! objective u32 (v2+; see Objective::tag — 0 multiclass, 1 multilabel,
//!                2 multilabel+plt; absent in v1, which loads multiclass)
//! n_history u64 | (examples u64, active_hinge u64,
//!                  loss_sum f64-bits, new_labels u64) * n_history
//! model_len u64 | model bytes (the "LTLS" format above, raw weights)
//! ```
//!
//! A checkpoint stores the *raw* (unaveraged, un-thresholded) weights plus
//! the global SGD step, so a resumed run continues the lr schedule and the
//! per-epoch shuffles exactly. The embedded model bytes carry the backend
//! tag, so a checkpoint of a hashed run resumes as hashed (and refuses to
//! resume under a different backend); the checkpoint header carries the
//! training [`crate::train::Objective`], so a multilabel checkpoint
//! refuses to resume as multiclass and vice versa. Not stored (restarts
//! fresh at resume): the weight-averager state and the assigner's
//! random-fallback RNG.

use crate::assign::{AssignPolicy, Assigner};
use crate::graph::{Topology, Trellis, WideTrellis};
use crate::model::hashed::HashedStore;
use crate::model::linear::DenseStore;
use crate::model::mmap::MmapRegion;
use crate::model::quant::Q8Store;
use crate::model::shard::ShardStore;
use crate::model::store::{parse_f32s, Backend, WeightBlock, WeightStore};
use crate::train::metrics::EpochMetrics;
use crate::train::{Objective, TrainedModel};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"LTLS";
/// v1: no width field (implicitly 2). v2: width u32 after C.
/// v3: backend tag + meta section + 64-byte-aligned trailing weight block.
const VERSION: u32 = 3;
/// v4: a v3 layout carrying a shard slice (shard framing after the
/// backend tag). Only [`serialize_shard`] writes it.
const SHARD_VERSION: u32 = 4;
const CKPT_MAGIC: &[u8; 4] = b"LTCK";
/// v1: no objective field (implicitly multiclass). v2: objective tag u32
/// after the seed.
const CKPT_VERSION: u32 = 2;
/// File alignment of the v3 weight block (cache-line sized; any mmap page
/// base is a multiple of it).
const WEIGHT_ALIGN: usize = 64;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        // `n` may come straight from an untrusted 64-bit length field, so
        // compare against the *remaining* bytes (`i ≤ len` always) — the
        // `i + n` form would overflow and panic on corrupt files.
        if n > self.b.len() - self.i {
            return Err(format!("truncated model file at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        Ok(parse_f32s(self.take(n * 4)?))
    }
    /// Skip to the next multiple-of-`a` offset (the v3 weight padding).
    fn align(&mut self, a: usize) -> Result<(), String> {
        let rem = self.i % a;
        if rem != 0 {
            self.take(a - rem)?;
        }
        Ok(())
    }
}

/// Serialize a trained model (any topology and weight backend; the file
/// records both).
pub fn serialize<T: Topology, S: WeightStore>(m: &TrainedModel<T, S>) -> Vec<u8> {
    serialize_parts(&m.trellis, &m.model, &m.assigner)
}

/// Borrowing variant of [`serialize`]: write a model straight from live
/// trainer state, without assembling (or cloning into) a `TrainedModel`.
pub fn serialize_parts<T: Topology, S: WeightStore>(
    trellis: &T,
    model: &S,
    assigner: &Assigner,
) -> Vec<u8> {
    assert!(
        model.shard_part().is_none(),
        "shard slices carry v4 framing; write them with `serialize_shard`"
    );
    let mut out = Vec::with_capacity(model.weight_block_len() + 4096);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u64(&mut out, trellis.c());
    put_u32(&mut out, trellis.width());
    put_u64(&mut out, model.n_features() as u64);
    put_u64(&mut out, model.n_edges() as u64);
    let pairs: Vec<(u32, u64)> = assigner.table.pairs().collect();
    let n_labels = pairs.iter().map(|&(l, _)| l as u64 + 1).max().unwrap_or(0);
    put_u64(&mut out, n_labels);
    put_u32(&mut out, model.backend().tag());
    let mut meta = Vec::new();
    model.write_meta(&mut meta);
    put_u64(&mut out, meta.len() as u64);
    out.extend_from_slice(&meta);
    for &b in model.bias() {
        out.extend_from_slice(&b.to_le_bytes());
    }
    put_u64(&mut out, pairs.len() as u64);
    for (l, p) in pairs {
        put_u32(&mut out, l);
        put_u64(&mut out, p);
    }
    put_u64(&mut out, model.weight_block_len() as u64);
    while out.len() % WEIGHT_ALIGN != 0 {
        out.push(0);
    }
    model.write_weights(&mut out);
    out
}

/// The v4 shard framing: which slice this file is and which full-model
/// edge columns it stores.
struct ShardHeader {
    n_shards: u32,
    shard_id: u32,
    owned: Vec<u32>,
}

/// The header fields shared by every version, plus where the body starts.
struct FileHeader {
    version: u32,
    c: u64,
    width: u32,
    d: usize,
    e: usize,
    n_labels: usize,
    backend: Backend,
    /// `Some` for v4 shard slices.
    shard: Option<ShardHeader>,
}

fn read_header(r: &mut Reader) -> Result<FileHeader, String> {
    if r.take(4)? != MAGIC {
        return Err("not an LTLS model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version == 0 || version > SHARD_VERSION {
        return Err(format!("unsupported model version {version}"));
    }
    let c = r.u64()?;
    let width = if version >= 2 { r.u32()? } else { 2 };
    let d = r.u64()? as usize;
    let e = r.u64()? as usize;
    let n_labels = r.u64()? as usize;
    let backend = if version >= 3 { Backend::from_tag(r.u32()?)? } else { Backend::Dense };
    let shard = if version >= SHARD_VERSION {
        let n_shards = r.u32()?;
        let shard_id = r.u32()?;
        let n_owned = r.u64()? as usize;
        if n_owned.saturating_mul(4) > r.b.len() {
            return Err("truncated model file (owned edges)".into());
        }
        let owned = parse_u32s(r.take(n_owned * 4)?);
        Some(ShardHeader { n_shards, shard_id, owned })
    } else {
        None
    };
    Ok(FileHeader { version, c, width, d, e, n_labels, backend, shard })
}

fn parse_u32s(bytes: &[u8]) -> Vec<u32> {
    bytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect()
}

/// Core deserializer: parses `bytes`, taking the weight block as a borrow
/// of `region` when mapped loading is requested (then `bytes` must be
/// `region.bytes()`).
fn deserialize_impl<T: Topology, S: WeightStore>(
    bytes: &[u8],
    region: Option<&Arc<MmapRegion>>,
) -> Result<TrainedModel<T, S>, String> {
    let mut r = Reader { b: bytes, i: 0 };
    let hdr = read_header(&mut r)?;
    if hdr.shard.is_some() {
        return Err(
            "file is a shard slice (model format v4); load it with `deserialize_any`/`load_any`"
                .into(),
        );
    }
    if hdr.backend != S::BACKEND {
        return Err(format!(
            "file stores a {} model, expected {} (load with `deserialize_any`/`load_any` \
             to dispatch on the stored backend)",
            hdr.backend.name(),
            S::BACKEND.name()
        ));
    }
    let trellis = T::build(hdr.c, hdr.width)?;
    if trellis.num_edges() != hdr.e {
        return Err(format!(
            "edge count mismatch: file {}, trellis {}",
            hdr.e,
            trellis.num_edges()
        ));
    }
    let (e, d) = (hdr.e, hdr.d);
    // The D×E products below come from untrusted file fields: reject
    // anything that cannot even be sized before multiplying.
    if d.checked_mul(e).and_then(|n| n.checked_mul(4)).is_none() {
        return Err(format!("implausible model dimensions D={d} E={e}"));
    }
    // Every label maps to one of the C paths, so a label count beyond C
    // is corrupt — and would otherwise *panic* the assignment-table
    // constructor (reload safety: a bad file must never take down a
    // serving process holding the old model).
    let n_labels = hdr.n_labels.max(1);
    if n_labels as u64 > hdr.c {
        return Err(format!(
            "corrupt model file: {n_labels} labels exceed C={} paths",
            hdr.c
        ));
    }
    let mut assigner = Assigner::new(AssignPolicy::Identity, n_labels, &trellis, 0);

    let model = if hdr.version <= 2 {
        // Old layout: bias | weights (dense f32) | pairs | EOF.
        let bias = r.f32s(e)?;
        let woff = r.i;
        let wlen = d * e * 4;
        r.take(wlen)?;
        let model = S::read_store(e, d, &[], bias, block_of(bytes, region, woff, wlen))?;
        let n_pairs = r.u64()? as usize;
        for _ in 0..n_pairs {
            let l = r.u32()?;
            let p = r.u64()?;
            bind_pair(&mut assigner, l, p, n_labels, hdr.c)?;
        }
        if r.i != bytes.len() {
            return Err(format!("{} trailing bytes", bytes.len() - r.i));
        }
        model
    } else {
        // v3 layout: meta | bias | pairs | wlen | pad | weights | EOF.
        let meta_len = r.u64()? as usize;
        if meta_len > bytes.len() {
            return Err("truncated model file (meta)".into());
        }
        let meta = r.take(meta_len)?.to_vec();
        let bias = r.f32s(e)?;
        let n_pairs = r.u64()? as usize;
        if n_pairs.saturating_mul(12) > bytes.len() {
            return Err("truncated model file (pairs)".into());
        }
        for _ in 0..n_pairs {
            let l = r.u32()?;
            let p = r.u64()?;
            bind_pair(&mut assigner, l, p, n_labels, hdr.c)?;
        }
        let wlen = r.u64()? as usize;
        r.align(WEIGHT_ALIGN)?;
        let woff = r.i;
        r.take(wlen)?;
        if r.i != bytes.len() {
            return Err(format!("{} trailing bytes", bytes.len() - r.i));
        }
        S::read_store(e, d, &meta, bias, block_of(bytes, region, woff, wlen))?
    };
    Ok(TrainedModel { trellis, model, assigner })
}

/// Bind a (label, path) pair read from an untrusted file, converting the
/// assignment table's panicking invariants (range, double binds) into
/// load errors — a corrupt file must never panic a process that is
/// hot-reloading it while serving the previous model.
fn bind_pair(
    assigner: &mut Assigner,
    l: u32,
    p: u64,
    n_labels: usize,
    c: u64,
) -> Result<(), String> {
    if l as usize >= n_labels {
        return Err(format!(
            "corrupt model file: label {l} out of range (n_labels {n_labels})"
        ));
    }
    if p >= c {
        return Err(format!("corrupt model file: path {p} out of range (C={c})"));
    }
    if assigner.table.path_of(l).is_some() {
        return Err(format!("corrupt model file: label {l} bound twice"));
    }
    if !assigner.table.is_free(p) {
        return Err(format!("corrupt model file: path {p} bound twice"));
    }
    assigner.table.bind(l, p);
    Ok(())
}

/// The weight block as a parse-copy borrow of `bytes`, or a zero-copy
/// borrow of the mapped `region` (when present, `bytes` is
/// `region.bytes()`, so `off`/`len` index both identically).
fn block_of<'a>(
    bytes: &'a [u8],
    region: Option<&Arc<MmapRegion>>,
    off: usize,
    len: usize,
) -> WeightBlock<'a> {
    match region {
        Some(reg) => WeightBlock::Mapped { region: Arc::clone(reg), offset: off, len },
        None => WeightBlock::Owned(&bytes[off..off + len]),
    }
}

/// Serialize a shard slice as a v4 model file: the v3 layout with the
/// shard framing (`n_shards | shard_id | owned edge list`) between the
/// backend tag and the meta section; `E` stays the full model's edge
/// count while bias/meta/weights are the sliced inner store's sections.
pub fn serialize_shard<T: Topology, S: WeightStore>(
    m: &TrainedModel<T, ShardStore<S>>,
) -> Vec<u8> {
    let store = &m.model;
    let inner = store.inner();
    let mut out = Vec::with_capacity(inner.weight_block_len() + 4096);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, SHARD_VERSION);
    put_u64(&mut out, m.trellis.c());
    put_u32(&mut out, m.trellis.width());
    put_u64(&mut out, inner.n_features() as u64);
    put_u64(&mut out, store.n_edges() as u64);
    let pairs: Vec<(u32, u64)> = m.assigner.table.pairs().collect();
    let n_labels = pairs.iter().map(|&(l, _)| l as u64 + 1).max().unwrap_or(0);
    put_u64(&mut out, n_labels);
    put_u32(&mut out, S::BACKEND.tag());
    put_u32(&mut out, store.n_shards());
    put_u32(&mut out, store.shard_id());
    put_u64(&mut out, store.owned_edges().len() as u64);
    for &e in store.owned_edges() {
        put_u32(&mut out, e);
    }
    let mut meta = Vec::new();
    inner.write_meta(&mut meta);
    put_u64(&mut out, meta.len() as u64);
    out.extend_from_slice(&meta);
    for &b in inner.bias() {
        out.extend_from_slice(&b.to_le_bytes());
    }
    put_u64(&mut out, pairs.len() as u64);
    for (l, p) in pairs {
        put_u32(&mut out, l);
        put_u64(&mut out, p);
    }
    put_u64(&mut out, inner.weight_block_len() as u64);
    while out.len() % WEIGHT_ALIGN != 0 {
        out.push(0);
    }
    inner.write_weights(&mut out);
    out
}

/// Save a shard slice to a file (v4 format).
pub fn save_shard<T: Topology, S: WeightStore>(
    m: &TrainedModel<T, ShardStore<S>>,
    path: &Path,
) -> Result<(), String> {
    let bytes = serialize_shard(m);
    let mut f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    f.write_all(&bytes).map_err(|e| e.to_string())
}

/// v4 counterpart of [`deserialize_impl`]: parse a shard slice, rebuild
/// the sliced inner store, and re-widen it behind a [`ShardStore`].
fn deserialize_shard_impl<T: Topology, S: WeightStore>(
    bytes: &[u8],
    region: Option<&Arc<MmapRegion>>,
) -> Result<TrainedModel<T, ShardStore<S>>, String> {
    let mut r = Reader { b: bytes, i: 0 };
    let hdr = read_header(&mut r)?;
    let Some(sh) = hdr.shard else {
        return Err("not a shard slice; load whole models with `deserialize`/`load_any`".into());
    };
    if hdr.backend != S::BACKEND {
        return Err(format!(
            "file stores a {} model, expected {} (load with `deserialize_any`/`load_any` \
             to dispatch on the stored backend)",
            hdr.backend.name(),
            S::BACKEND.name()
        ));
    }
    let trellis = T::build(hdr.c, hdr.width)?;
    if trellis.num_edges() != hdr.e {
        return Err(format!(
            "edge count mismatch: file {}, trellis {}",
            hdr.e,
            trellis.num_edges()
        ));
    }
    let (e, d) = (hdr.e, hdr.d);
    if d.checked_mul(e).and_then(|n| n.checked_mul(4)).is_none() {
        return Err(format!("implausible model dimensions D={d} E={e}"));
    }
    let n_labels = hdr.n_labels.max(1);
    if n_labels as u64 > hdr.c {
        return Err(format!(
            "corrupt model file: {n_labels} labels exceed C={} paths",
            hdr.c
        ));
    }
    let mut assigner = Assigner::new(AssignPolicy::Identity, n_labels, &trellis, 0);
    // v3-style body over the sliced sections: bias/meta/weights are the
    // owned columns, the pairs table is the full one.
    let n_owned = sh.owned.len();
    let meta_len = r.u64()? as usize;
    if meta_len > bytes.len() {
        return Err("truncated model file (meta)".into());
    }
    let meta = r.take(meta_len)?.to_vec();
    let bias = r.f32s(n_owned)?;
    let n_pairs = r.u64()? as usize;
    if n_pairs.saturating_mul(12) > bytes.len() {
        return Err("truncated model file (pairs)".into());
    }
    for _ in 0..n_pairs {
        let l = r.u32()?;
        let p = r.u64()?;
        bind_pair(&mut assigner, l, p, n_labels, hdr.c)?;
    }
    let wlen = r.u64()? as usize;
    r.align(WEIGHT_ALIGN)?;
    let woff = r.i;
    r.take(wlen)?;
    if r.i != bytes.len() {
        return Err(format!("{} trailing bytes", bytes.len() - r.i));
    }
    let inner = S::read_store(n_owned, d, &meta, bias, block_of(bytes, region, woff, wlen))?;
    let store = ShardStore::from_parts(inner, sh.owned, e, sh.shard_id, sh.n_shards)?;
    Ok(TrainedModel { trellis, model: store, assigner })
}

/// Deserialize a trained model as topology `T` and weight store `S`.
/// Errors if the file's stored width or backend is one `(T, S)` cannot
/// represent; use [`deserialize_any`] to dispatch on the stored pair.
pub fn deserialize<T: Topology, S: WeightStore>(
    bytes: &[u8],
) -> Result<TrainedModel<T, S>, String> {
    deserialize_impl(bytes, None)
}

/// Deserialize borrowing the weight block from a mapped file region
/// (zero-copy: only header, bias, meta and the label↔path table are
/// materialized on the heap).
pub fn deserialize_mapped<T: Topology, S: WeightStore>(
    region: &Arc<MmapRegion>,
) -> Result<TrainedModel<T, S>, String> {
    deserialize_impl(region.bytes(), Some(region))
}

/// Save to a file.
pub fn save<T: Topology, S: WeightStore>(
    m: &TrainedModel<T, S>,
    path: &Path,
) -> Result<(), String> {
    let bytes = serialize(m);
    let mut f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    f.write_all(&bytes).map_err(|e| e.to_string())
}

/// Load from a file as topology `T` and store `S`.
pub fn load<T: Topology, S: WeightStore>(path: &Path) -> Result<TrainedModel<T, S>, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    deserialize(&bytes)
}

/// A loaded model whose topology **and weight backend** were chosen by the
/// file: width 2 gets the canonical [`Trellis`] (register-specialized
/// decode kernels), anything else a [`WideTrellis`]; the backend tag picks
/// dense / hashed / q8. This is how the CLI serves and evaluates model
/// files of any shape.
pub enum AnyModel {
    Binary(TrainedModel<Trellis, DenseStore>),
    Wide(TrainedModel<WideTrellis, DenseStore>),
    BinaryHashed(TrainedModel<Trellis, HashedStore>),
    WideHashed(TrainedModel<WideTrellis, HashedStore>),
    BinaryQ8(TrainedModel<Trellis, Q8Store>),
    WideQ8(TrainedModel<WideTrellis, Q8Store>),
    BinaryShard(TrainedModel<Trellis, ShardStore<DenseStore>>),
    WideShard(TrainedModel<WideTrellis, ShardStore<DenseStore>>),
    BinaryHashedShard(TrainedModel<Trellis, ShardStore<HashedStore>>),
    WideHashedShard(TrainedModel<WideTrellis, ShardStore<HashedStore>>),
    BinaryQ8Shard(TrainedModel<Trellis, ShardStore<Q8Store>>),
    WideQ8Shard(TrainedModel<WideTrellis, ShardStore<Q8Store>>),
}

/// Run `$body` with `$m` bound to the concrete [`AnyModel`] variant — the
/// 12-way (width × backend × whole-or-shard) dispatch in one place.
#[macro_export]
macro_rules! with_any_model {
    ($any:expr, $m:ident => $body:expr) => {
        match $any {
            $crate::model::io::AnyModel::Binary($m) => $body,
            $crate::model::io::AnyModel::Wide($m) => $body,
            $crate::model::io::AnyModel::BinaryHashed($m) => $body,
            $crate::model::io::AnyModel::WideHashed($m) => $body,
            $crate::model::io::AnyModel::BinaryQ8($m) => $body,
            $crate::model::io::AnyModel::WideQ8($m) => $body,
            $crate::model::io::AnyModel::BinaryShard($m) => $body,
            $crate::model::io::AnyModel::WideShard($m) => $body,
            $crate::model::io::AnyModel::BinaryHashedShard($m) => $body,
            $crate::model::io::AnyModel::WideHashedShard($m) => $body,
            $crate::model::io::AnyModel::BinaryQ8Shard($m) => $body,
            $crate::model::io::AnyModel::WideQ8Shard($m) => $body,
        }
    };
}

impl AnyModel {
    /// Number of classes.
    pub fn c(&self) -> u64 {
        crate::with_any_model!(self, m => m.trellis.c())
    }

    /// Trellis width.
    pub fn width(&self) -> u32 {
        crate::with_any_model!(self, m => m.trellis.width())
    }

    /// Number of learnable edges.
    pub fn num_edges(&self) -> usize {
        crate::with_any_model!(self, m => m.trellis.num_edges())
    }

    /// Logical feature dimensionality `D`.
    pub fn n_features(&self) -> usize {
        crate::with_any_model!(self, m => m.model.n_features())
    }

    /// Weight-storage backend.
    pub fn backend(&self) -> Backend {
        crate::with_any_model!(self, m => m.model.backend())
    }

    /// Stored model size in bytes.
    pub fn bytes(&self) -> usize {
        crate::with_any_model!(self, m => m.model.bytes())
    }

    /// Size after dropping exactly-zero weights.
    pub fn effective_bytes(&self) -> usize {
        crate::with_any_model!(self, m => m.model.effective_bytes())
    }

    /// Fraction of exactly-zero stored weights.
    pub fn zero_fraction(&self) -> f64 {
        crate::with_any_model!(self, m => m.model.zero_fraction())
    }

    /// True when the weights borrow a mapped file region.
    pub fn is_mapped(&self) -> bool {
        crate::with_any_model!(self, m => m.model.is_mapped())
    }

    /// `(shard_id, n_shards)` when this is a v4 shard slice.
    pub fn shard_part(&self) -> Option<(u32, u32)> {
        crate::with_any_model!(self, m => m.model.shard_part())
    }
}

/// Peek a model file's header: `(C, width)` without building anything.
pub fn peek_meta(bytes: &[u8]) -> Result<(u64, u32), String> {
    let mut r = Reader { b: bytes, i: 0 };
    let hdr = read_header(&mut r)?;
    Ok((hdr.c, hdr.width))
}

/// Peek a model file's weight backend (v1/v2 files are dense).
pub fn peek_backend(bytes: &[u8]) -> Result<Backend, String> {
    let mut r = Reader { b: bytes, i: 0 };
    Ok(read_header(&mut r)?.backend)
}

fn dispatch_any(
    bytes: &[u8],
    region: Option<&Arc<MmapRegion>>,
) -> Result<AnyModel, String> {
    let mut r = Reader { b: bytes, i: 0 };
    let hdr = read_header(&mut r)?;
    let binary = hdr.width == 2;
    let sharded = hdr.shard.is_some();
    Ok(match (binary, hdr.backend, sharded) {
        (true, Backend::Dense, false) => AnyModel::Binary(deserialize_impl(bytes, region)?),
        (false, Backend::Dense, false) => AnyModel::Wide(deserialize_impl(bytes, region)?),
        (true, Backend::Hashed, false) => {
            AnyModel::BinaryHashed(deserialize_impl(bytes, region)?)
        }
        (false, Backend::Hashed, false) => {
            AnyModel::WideHashed(deserialize_impl(bytes, region)?)
        }
        (true, Backend::Q8, false) => AnyModel::BinaryQ8(deserialize_impl(bytes, region)?),
        (false, Backend::Q8, false) => AnyModel::WideQ8(deserialize_impl(bytes, region)?),
        (true, Backend::Dense, true) => {
            AnyModel::BinaryShard(deserialize_shard_impl(bytes, region)?)
        }
        (false, Backend::Dense, true) => {
            AnyModel::WideShard(deserialize_shard_impl(bytes, region)?)
        }
        (true, Backend::Hashed, true) => {
            AnyModel::BinaryHashedShard(deserialize_shard_impl(bytes, region)?)
        }
        (false, Backend::Hashed, true) => {
            AnyModel::WideHashedShard(deserialize_shard_impl(bytes, region)?)
        }
        (true, Backend::Q8, true) => {
            AnyModel::BinaryQ8Shard(deserialize_shard_impl(bytes, region)?)
        }
        (false, Backend::Q8, true) => {
            AnyModel::WideQ8Shard(deserialize_shard_impl(bytes, region)?)
        }
    })
}

/// Deserialize dispatching on the stored (width, backend) pair (see
/// [`AnyModel`]).
pub fn deserialize_any(bytes: &[u8]) -> Result<AnyModel, String> {
    dispatch_any(bytes, None)
}

/// Load from a file dispatching on the stored (width, backend) pair.
pub fn load_any(path: &Path) -> Result<AnyModel, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    deserialize_any(&bytes)
}

/// Memory-mapped [`load_any`]: the weight block is borrowed zero-copy from
/// the mapping — serving starts without materializing it (`ltls serve
/// --mmap`).
pub fn load_any_mmap(path: &Path) -> Result<AnyModel, String> {
    let region = Arc::new(MmapRegion::map(path)?);
    dispatch_any(region.bytes(), Some(&region))
}

/// An epoch-boundary training checkpoint (see the module docs for the
/// on-disk format and what is / is not restored). Generic over the
/// topology and weight store — the embedded model bytes carry the width
/// and the backend tag.
#[derive(Clone)]
pub struct Checkpoint<T: Topology = Trellis, S: WeightStore = DenseStore> {
    /// Epochs completed when this checkpoint was taken.
    pub epoch: u32,
    /// Global SGD step (examples seen), driving the lr schedule and the
    /// per-epoch shuffle salts.
    pub step: u64,
    /// The training seed (sanity: resume with the same-seeded config).
    pub seed: u64,
    /// The training objective (sanity: a multilabel checkpoint refuses to
    /// resume as multiclass and vice versa). v1 files load as multiclass.
    pub objective: Objective,
    /// Per-epoch metrics, oldest first.
    pub history: Vec<EpochMetrics>,
    /// Raw (unaveraged) weights + trellis + label↔path table.
    pub model: TrainedModel<T, S>,
}

/// Serialize a checkpoint.
pub fn serialize_checkpoint<T: Topology, S: WeightStore>(ck: &Checkpoint<T, S>) -> Vec<u8> {
    serialize_checkpoint_with(
        ck.epoch,
        ck.step,
        ck.seed,
        ck.objective,
        &ck.history,
        &serialize(&ck.model),
    )
}

/// Low-level checkpoint writer over pre-serialized model bytes. Combined
/// with [`serialize_parts`] this lets the trainer checkpoint every epoch
/// without cloning its weight matrix into a temporary `TrainedModel`.
pub fn serialize_checkpoint_with(
    epoch: u32,
    step: u64,
    seed: u64,
    objective: Objective,
    history: &[EpochMetrics],
    model_bytes: &[u8],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(model_bytes.len() + 64 + history.len() * 32);
    out.extend_from_slice(CKPT_MAGIC);
    put_u32(&mut out, CKPT_VERSION);
    put_u32(&mut out, epoch);
    put_u64(&mut out, step);
    put_u64(&mut out, seed);
    put_u32(&mut out, objective.tag());
    put_u64(&mut out, history.len() as u64);
    for m in history {
        put_u64(&mut out, m.examples);
        put_u64(&mut out, m.active_hinge);
        put_u64(&mut out, m.loss_sum.to_bits());
        put_u64(&mut out, m.new_labels);
    }
    put_u64(&mut out, model_bytes.len() as u64);
    out.extend_from_slice(model_bytes);
    out
}

/// Deserialize a checkpoint as topology `T` and store `S` (errors if the
/// embedded model was trained at a width or backend `(T, S)` cannot
/// represent).
pub fn deserialize_checkpoint<T: Topology, S: WeightStore>(
    bytes: &[u8],
) -> Result<Checkpoint<T, S>, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != CKPT_MAGIC {
        return Err("not an LTLS checkpoint file (bad magic)".into());
    }
    let version = r.u32()?;
    if version == 0 || version > CKPT_VERSION {
        return Err(format!("unsupported checkpoint version {version}"));
    }
    let epoch = r.u32()?;
    let step = r.u64()?;
    let seed = r.u64()?;
    // v1 predates the objective field: those runs were all multiclass.
    let objective =
        if version >= 2 { Objective::from_tag(r.u32()?)? } else { Objective::Multiclass };
    let n_history = r.u64()? as usize;
    if n_history.saturating_mul(32) > bytes.len() {
        return Err("truncated checkpoint (history)".into());
    }
    let mut history = Vec::with_capacity(n_history);
    for _ in 0..n_history {
        let examples = r.u64()?;
        let active_hinge = r.u64()?;
        let loss_sum = f64::from_bits(r.u64()?);
        let new_labels = r.u64()?;
        history.push(EpochMetrics { examples, active_hinge, loss_sum, new_labels });
    }
    let model_len = r.u64()? as usize;
    let model = deserialize(r.take(model_len)?)?;
    if r.i != bytes.len() {
        return Err(format!("{} trailing bytes", bytes.len() - r.i));
    }
    Ok(Checkpoint { epoch, step, seed, objective, history, model })
}

/// Peek the backend tag of the model embedded in a checkpoint file's
/// bytes (for CLI dispatch before committing to a store type).
pub fn peek_checkpoint_backend(bytes: &[u8]) -> Result<Backend, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != CKPT_MAGIC {
        return Err("not an LTLS checkpoint file (bad magic)".into());
    }
    let version = r.u32()?;
    if version == 0 || version > CKPT_VERSION {
        return Err(format!("unsupported checkpoint version {version}"));
    }
    let _ = r.u32()?; // epoch
    let _ = r.u64()?; // step
    let _ = r.u64()?; // seed
    if version >= 2 {
        let _ = r.u32()?; // objective tag
    }
    let n_history = r.u64()? as usize;
    if n_history.saturating_mul(32) > bytes.len() {
        return Err("truncated checkpoint (history)".into());
    }
    r.take(n_history * 32)?;
    let model_len = r.u64()? as usize;
    peek_backend(r.take(model_len)?)
}

/// Save a checkpoint, atomically: write to `<path>.tmp`, then rename, so a
/// crash mid-write never clobbers the previous checkpoint.
pub fn save_checkpoint<T: Topology, S: WeightStore>(
    ck: &Checkpoint<T, S>,
    path: &Path,
) -> Result<(), String> {
    write_atomic(&serialize_checkpoint(ck), path)
}

/// Atomic file replace (`<path>.tmp` + rename).
pub fn write_atomic(bytes: &[u8], path: &Path) -> Result<(), String> {
    let tmp = path.with_extension("ltck.tmp");
    std::fs::write(&tmp, bytes).map_err(|e| format!("{}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))
}

/// Load a checkpoint from a file as topology `T` and store `S`.
pub fn load_checkpoint<T: Topology, S: WeightStore>(
    path: &Path,
) -> Result<Checkpoint<T, S>, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    deserialize_checkpoint(&bytes)
}

/// Canonical checkpoint file name for an epoch: `dir/epoch-NNNN.ltck`.
pub fn checkpoint_path(dir: &Path, epoch: u32) -> PathBuf {
    dir.join(format!("epoch-{epoch:04}.ltck"))
}

/// Delete every `epoch-NNNN.ltck` (and stray `.ltck.tmp`) in `dir`;
/// returns how many files were removed. A *fresh* training run pointed at
/// a dir that still holds an older run's checkpoints must clear them,
/// otherwise a later `--resume` would pick up the stale run's
/// higher-numbered epochs instead of the new run's.
pub fn clear_checkpoints(dir: &Path) -> Result<usize, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut removed = 0usize;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let is_ckpt = name
            .strip_prefix("epoch-")
            .and_then(|s| s.strip_suffix(".ltck").or_else(|| s.strip_suffix(".ltck.tmp")))
            .map(|num| num.parse::<u32>().is_ok())
            .unwrap_or(false);
        if is_ckpt {
            std::fs::remove_file(entry.path())
                .map_err(|e| format!("{}: {e}", entry.path().display()))?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// The highest-epoch `epoch-NNNN.ltck` in `dir`, if any.
pub fn latest_checkpoint(dir: &Path) -> Result<Option<(u32, PathBuf)>, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut best: Option<(u32, PathBuf)> = None;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(num) = name.strip_prefix("epoch-").and_then(|s| s.strip_suffix(".ltck")) else {
            continue;
        };
        let Ok(epoch) = num.parse::<u32>() else { continue };
        if best.as_ref().map(|(b, _)| epoch > *b).unwrap_or(true) {
            best = Some((epoch, entry.path()));
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::Predictor;
    use crate::train::{TrainConfig, Trainer};

    fn trained() -> (TrainedModel, crate::data::Dataset) {
        let ds = SyntheticSpec::multiclass(600, 400, 24).seed(61).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        (tr.into_model(), ds)
    }

    /// Re-create the retired v2 layout (header | bias | weights | pairs)
    /// for the back-compat tests: the current serializer only emits v3.
    fn write_v2(m: &TrainedModel) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        put_u32(&mut out, 2);
        put_u64(&mut out, m.trellis.c);
        put_u32(&mut out, 2);
        put_u64(&mut out, m.model.n_features as u64);
        put_u64(&mut out, m.model.n_edges as u64);
        let pairs: Vec<(u32, u64)> = m.assigner.table.pairs().collect();
        let n_labels = pairs.iter().map(|&(l, _)| l as u64 + 1).max().unwrap_or(0);
        put_u64(&mut out, n_labels);
        for &b in &m.model.bias {
            out.extend_from_slice(&b.to_le_bytes());
        }
        for &w in m.model.w.iter() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        put_u64(&mut out, pairs.len() as u64);
        for (l, p) in pairs {
            put_u32(&mut out, l);
            put_u64(&mut out, p);
        }
        out
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        let (m, ds) = trained();
        let bytes = serialize(&m);
        let m2 = deserialize::<Trellis, DenseStore>(&bytes).unwrap();
        assert_eq!(m2.trellis.c, m.trellis.c);
        assert_eq!(m2.model.w, m.model.w);
        for i in 0..50 {
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
    }

    #[test]
    fn v3_weight_block_is_64_byte_aligned_and_last() {
        let (m, _) = trained();
        let bytes = serialize(&m);
        let wlen = m.model.w.len() * 4;
        assert!(bytes.len() >= wlen);
        // The weight block closes the file and starts at a 64-byte offset.
        assert_eq!(
            (bytes.len() - wlen) % WEIGHT_ALIGN,
            0,
            "weight block must start 64-byte aligned"
        );
        let tail = &bytes[bytes.len() - wlen..];
        let parsed = parse_f32s(tail);
        assert_eq!(parsed.as_slice(), &m.model.w[..]);
        assert_eq!(peek_backend(&bytes).unwrap(), Backend::Dense);
    }

    #[test]
    fn file_roundtrip() {
        let (m, _) = trained();
        let path = std::env::temp_dir().join("ltls_model_io_test.bin");
        save(&m, &path).unwrap();
        let m2 = load::<Trellis, DenseStore>(&path).unwrap();
        assert_eq!(m2.model.bias, m.model.bias);
        std::fs::remove_file(&path).ok();
    }

    /// A wide model round-trips: the file carries its width, `load_any`
    /// dispatches on it, and `deserialize::<Trellis, _>` rejects it.
    #[test]
    fn wide_model_roundtrip_and_dispatch() {
        let ds = SyntheticSpec::multiclass(500, 300, 24).seed(62).generate();
        let cfg = TrainConfig { width: 4, ..TrainConfig::default() };
        let mut tr = crate::train::Trainer::<crate::graph::WideTrellis>::with_topology(
            cfg,
            ds.n_features,
            ds.n_labels,
        )
        .unwrap();
        tr.fit(&ds, 2);
        let m = tr.into_model();
        let bytes = serialize(&m);
        assert_eq!(peek_meta(&bytes).unwrap(), (24, 4));

        let m2 = deserialize::<WideTrellis, DenseStore>(&bytes).unwrap();
        assert_eq!(m2.model.w, m.model.w);
        for i in 0..30 {
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
        match deserialize_any(&bytes).unwrap() {
            AnyModel::Wide(w) => assert_eq!(w.trellis.width(), 4),
            _ => panic!("width-4 dense file dispatched to the wrong variant"),
        }
        let err = deserialize::<Trellis, DenseStore>(&bytes).unwrap_err();
        assert!(err.contains("width"), "{err}");
        // Width-2 files still dispatch to the specialized Trellis.
        let (m2w, _) = trained();
        match deserialize_any(&serialize(&m2w)).unwrap() {
            AnyModel::Binary(b) => assert_eq!(b.trellis.width(), 2),
            _ => panic!("width-2 dense file dispatched to the wrong variant"),
        }
    }

    /// Version-2 files (pre-backend layout) and version-1 files (no width
    /// field) still load, as dense.
    #[test]
    fn v1_and_v2_layouts_load_as_dense() {
        let (m, ds) = trained();
        let v2 = write_v2(&m);
        assert_eq!(peek_meta(&v2).unwrap(), (m.trellis.c, 2));
        assert_eq!(peek_backend(&v2).unwrap(), Backend::Dense);
        let m2 = deserialize::<Trellis, DenseStore>(&v2).unwrap();
        assert_eq!(m2.model.w, m.model.w);
        // Rewrite the header to v1: patch the version field and remove the
        // width u32 at bytes 16..20 (after magic+version+C).
        let mut v1 = Vec::with_capacity(v2.len() - 4);
        v1.extend_from_slice(&v2[..4]);
        v1.extend_from_slice(&1u32.to_le_bytes());
        v1.extend_from_slice(&v2[8..16]);
        v1.extend_from_slice(&v2[20..]);
        assert_eq!(peek_meta(&v1).unwrap(), (m.trellis.c, 2));
        let m1 = deserialize::<Trellis, DenseStore>(&v1).unwrap();
        assert_eq!(m1.model.w, m.model.w);
        for i in 0..20 {
            assert_eq!(m.topk(ds.row(i), 3), m1.topk(ds.row(i), 3), "row {i}");
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
        // Old layouts load as dense only: a hashed-typed load must refuse.
        let err = deserialize::<Trellis, HashedStore>(&v2).unwrap_err();
        assert!(err.contains("dense"), "{err}");
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (m, _) = trained();
        let ck = Checkpoint {
            epoch: 3,
            step: 1234,
            seed: 42,
            objective: Objective::Multilabel { plt_weight: true },
            history: vec![
                EpochMetrics { examples: 400, active_hinge: 300, loss_sum: 99.5, new_labels: 24 },
                EpochMetrics { examples: 400, active_hinge: 120, loss_sum: 31.25, new_labels: 0 },
            ],
            model: m,
        };
        let bytes = serialize_checkpoint(&ck);
        let ck2 = deserialize_checkpoint::<Trellis, DenseStore>(&bytes).unwrap();
        assert_eq!(ck2.epoch, 3);
        assert_eq!(ck2.step, 1234);
        assert_eq!(ck2.seed, 42);
        assert_eq!(ck2.objective, Objective::Multilabel { plt_weight: true });
        assert_eq!(ck2.history.len(), 2);
        assert_eq!(ck2.history[0].examples, 400);
        assert_eq!(ck2.history[1].loss_sum, 31.25);
        assert_eq!(ck2.model.model.w, ck.model.model.w);
        assert_eq!(ck2.model.model.bias, ck.model.model.bias);
        // The embedded model carries the dense backend tag.
        assert_eq!(peek_checkpoint_backend(&bytes).unwrap(), Backend::Dense);
        // The embedded assignment table round-trips.
        let a: Vec<_> = ck.model.assigner.table.pairs().collect();
        let b: Vec<_> = ck2.model.assigner.table.pairs().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn checkpoint_rejects_corrupt_and_foreign_files() {
        let (m, _) = trained();
        let ck = Checkpoint {
            epoch: 1,
            step: 10,
            seed: 7,
            objective: Objective::Multiclass,
            history: vec![],
            model: m,
        };
        let mut bytes = serialize_checkpoint(&ck);
        assert!(deserialize_checkpoint::<Trellis, DenseStore>(&bytes[..16]).is_err()); // truncated
        bytes.push(0);
        assert!(deserialize_checkpoint::<Trellis, DenseStore>(&bytes).is_err()); // trailing garbage
        bytes.pop();
        bytes[0] = b'X';
        assert!(deserialize_checkpoint::<Trellis, DenseStore>(&bytes).is_err()); // bad magic
        // A plain model file is not a checkpoint (and vice versa).
        let (m2, _) = trained();
        assert!(deserialize_checkpoint::<Trellis, DenseStore>(&serialize(&m2)).is_err());
        let ck2 = Checkpoint {
            epoch: 1,
            step: 10,
            seed: 7,
            objective: Objective::Multiclass,
            history: vec![],
            model: m2,
        };
        assert!(deserialize::<Trellis, DenseStore>(&serialize_checkpoint(&ck2)).is_err());
    }

    /// A v1 checkpoint (no objective field) still loads — as multiclass —
    /// and a bogus objective tag or future version is refused.
    #[test]
    fn checkpoint_v1_compat_and_bad_objective() {
        let (m, _) = trained();
        let ck = Checkpoint {
            epoch: 2,
            step: 55,
            seed: 9,
            objective: Objective::Multiclass,
            history: vec![EpochMetrics {
                examples: 10,
                active_hinge: 4,
                loss_sum: 1.5,
                new_labels: 3,
            }],
            model: m,
        };
        let v2 = serialize_checkpoint(&ck);

        // Hand-build the v1 layout: same bytes minus the objective u32 at
        // offset 28 (after magic 4 | version 4 | epoch 4 | step 8 | seed 8),
        // with the version field rewritten to 1.
        let mut v1 = v2.clone();
        v1.drain(28..32);
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        let ck1 = deserialize_checkpoint::<Trellis, DenseStore>(&v1).unwrap();
        assert_eq!(ck1.objective, Objective::Multiclass);
        assert_eq!(ck1.step, 55);
        assert_eq!(ck1.history.len(), 1);
        assert_eq!(peek_checkpoint_backend(&v1).unwrap(), Backend::Dense);

        // Unknown objective tag in a v2 file.
        let mut bad_tag = v2.clone();
        bad_tag[28..32].copy_from_slice(&7u32.to_le_bytes());
        let err = deserialize_checkpoint::<Trellis, DenseStore>(&bad_tag).unwrap_err();
        assert!(err.contains("objective tag"), "{err}");

        // Future version.
        let mut v3 = v2;
        v3[4..8].copy_from_slice(&3u32.to_le_bytes());
        assert!(deserialize_checkpoint::<Trellis, DenseStore>(&v3).is_err());
        assert!(peek_checkpoint_backend(&v3).is_err());
    }

    #[test]
    fn checkpoint_dir_save_load_latest() {
        let dir = std::env::temp_dir().join(format!("ltls_ckpt_io_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (m, _) = trained();
        for epoch in [1u32, 2, 10] {
            let ck = Checkpoint {
                epoch,
                step: epoch as u64 * 100,
                seed: 42,
                objective: Objective::Multiclass,
                history: vec![],
                model: m.clone(),
            };
            save_checkpoint(&ck, &checkpoint_path(&dir, epoch)).unwrap();
        }
        let (epoch, path) = latest_checkpoint(&dir).unwrap().expect("checkpoints exist");
        assert_eq!(epoch, 10);
        let ck = load_checkpoint::<Trellis, DenseStore>(&path).unwrap();
        assert_eq!(ck.epoch, 10);
        assert_eq!(ck.step, 1000);
        // No tmp files left behind by the atomic writes.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_checkpoint_empty_dir_is_none() {
        let dir = std::env::temp_dir().join(format!("ltls_ckpt_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(latest_checkpoint(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clear_checkpoints_removes_only_checkpoint_files() {
        let dir = std::env::temp_dir().join(format!("ltls_ckpt_clear_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("epoch-0001.ltck"), b"x").unwrap();
        std::fs::write(dir.join("epoch-0007.ltck.tmp"), b"x").unwrap();
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        std::fs::write(dir.join("epoch-abc.ltck"), b"keep me too").unwrap();
        assert_eq!(clear_checkpoints(&dir).unwrap(), 2);
        assert!(latest_checkpoint(&dir).unwrap().is_none());
        assert!(dir.join("notes.txt").exists());
        assert!(dir.join("epoch-abc.ltck").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A v4 shard slice round-trips through serialize/load (plain and
    /// mmap), dispatches to the shard variant, and the typed v3 loaders
    /// refuse it cleanly.
    #[test]
    fn shard_slice_v4_roundtrip_and_dispatch() {
        use crate::graph::ShardPlan;
        use crate::model::shard::slice_model;
        let (m, ds) = trained();
        let plan = ShardPlan::new(&m.trellis, 2).unwrap();
        let sm = slice_model(&m, &plan, 1).unwrap();
        let bytes = serialize_shard(&sm);
        assert_eq!(peek_meta(&bytes).unwrap(), (m.trellis.c, 2));
        assert_eq!(peek_backend(&bytes).unwrap(), Backend::Dense);

        let any = deserialize_any(&bytes).unwrap();
        assert_eq!(any.shard_part(), Some((1, 2)));
        assert_eq!(any.num_edges(), m.trellis.num_edges());
        let AnyModel::BinaryShard(loaded) = any else {
            panic!("v4 width-2 dense slice dispatched to the wrong variant");
        };
        // The loaded slice predicts bit-identically to the in-memory one.
        for i in 0..30 {
            assert_eq!(sm.topk(ds.row(i), 3), loaded.topk(ds.row(i), 3), "row {i}");
        }
        // …including through the mmap path.
        let path = std::env::temp_dir()
            .join(format!("ltls_shard_v4_{}.ltls", std::process::id()));
        save_shard(&sm, &path).unwrap();
        let mapped = load_any_mmap(&path).unwrap();
        assert!(mapped.is_mapped());
        assert_eq!(mapped.shard_part(), Some((1, 2)));
        crate::with_any_model!(&mapped, mm => {
            for i in 0..10 {
                assert_eq!(sm.topk(ds.row(i), 3), mm.topk(ds.row(i), 3), "mmap row {i}");
            }
        });
        drop(mapped);
        std::fs::remove_file(&path).ok();
        // The typed v3 loader refuses a slice with a pointer to load_any.
        let err = deserialize::<Trellis, DenseStore>(&bytes).unwrap_err();
        assert!(err.contains("shard slice"), "{err}");
        // A truncated slice errors instead of panicking.
        assert!(deserialize_any(&bytes[..bytes.len() / 2]).is_err());
    }

    #[test]
    fn rejects_corrupt_files() {
        let (m, _) = trained();
        let mut bytes = serialize(&m);
        assert!(deserialize::<Trellis, DenseStore>(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(deserialize::<Trellis, DenseStore>(&bytes).is_err()); // bad magic
        let (m2, _) = trained();
        let mut ok = serialize(&m2);
        ok.push(0); // trailing garbage
        assert!(deserialize::<Trellis, DenseStore>(&ok).is_err());
        // Unknown backend tag errors cleanly.
        let mut bad_tag = serialize(&m2);
        // backend u32 sits right after the 44-byte v3 header prefix
        // (magic 4 | version 4 | C 8 | width 4 | D 8 | E 8 | n_labels 8).
        bad_tag[44] = 9;
        let err = deserialize_any(&bad_tag).unwrap_err();
        assert!(err.contains("backend tag"), "{err}");
        // A hostile D field (u64::MAX) errors instead of overflowing the
        // D·E·4 size arithmetic.
        let mut bad_d = serialize(&m2);
        bad_d[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = deserialize_any(&bad_d).unwrap_err();
        assert!(err.contains("implausible"), "{err}");
    }
}
