//! Model persistence: save / load a trained LTLS model (weights + trellis
//! + label↔path assignment) as a single self-describing binary file, so
//! `ltls train` can hand a model to `ltls serve` / `ltls eval` across
//! processes.
//!
//! Format (little-endian):
//! ```text
//! magic "LTLS" | version u32 | C u64 | D u64 | E u64 | n_labels u64
//! bias  [E f32] | weights [D*E f32, feature-major]
//! n_pairs u64 | (label u32, path u64) * n_pairs
//! ```

use crate::assign::{AssignPolicy, Assigner};
use crate::graph::Trellis;
use crate::model::LinearEdgeModel;
use crate::train::TrainedModel;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"LTLS";
const VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err(format!("truncated model file at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Serialize a trained model.
pub fn serialize(m: &TrainedModel) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + m.model.w.len() * 4);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u64(&mut out, m.trellis.c);
    put_u64(&mut out, m.model.n_features as u64);
    put_u64(&mut out, m.model.n_edges as u64);
    let pairs: Vec<(u32, u64)> = m.assigner.table.pairs().collect();
    let n_labels = pairs.iter().map(|&(l, _)| l as u64 + 1).max().unwrap_or(0);
    put_u64(&mut out, n_labels);
    for &b in &m.model.bias {
        out.extend_from_slice(&b.to_le_bytes());
    }
    for &w in &m.model.w {
        out.extend_from_slice(&w.to_le_bytes());
    }
    put_u64(&mut out, pairs.len() as u64);
    for (l, p) in pairs {
        put_u32(&mut out, l);
        put_u64(&mut out, p);
    }
    out
}

/// Deserialize a trained model.
pub fn deserialize(bytes: &[u8]) -> Result<TrainedModel, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        return Err("not an LTLS model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version != VERSION {
        return Err(format!("unsupported model version {version}"));
    }
    let c = r.u64()?;
    let d = r.u64()? as usize;
    let e = r.u64()? as usize;
    let n_labels = r.u64()? as usize;
    let trellis = Trellis::new(c);
    if trellis.num_edges() != e {
        return Err(format!("edge count mismatch: file {e}, trellis {}", trellis.num_edges()));
    }
    let bias = r.f32s(e)?;
    let w = r.f32s(d * e)?;
    let mut model = LinearEdgeModel::new(e, d);
    model.bias = bias;
    model.w = w;
    let mut assigner = Assigner::new(AssignPolicy::Identity, n_labels.max(1), &trellis, 0);
    let n_pairs = r.u64()? as usize;
    for _ in 0..n_pairs {
        let l = r.u32()?;
        let p = r.u64()?;
        assigner.table.bind(l, p);
    }
    if r.i != bytes.len() {
        return Err(format!("{} trailing bytes", bytes.len() - r.i));
    }
    Ok(TrainedModel { trellis, model, assigner })
}

/// Save to a file.
pub fn save(m: &TrainedModel, path: &Path) -> Result<(), String> {
    let bytes = serialize(m);
    let mut f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    f.write_all(&bytes).map_err(|e| e.to_string())
}

/// Load from a file.
pub fn load(path: &Path) -> Result<TrainedModel, String> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("{}: {e}", path.display()))?
        .read_to_end(&mut bytes)
        .map_err(|e| e.to_string())?;
    deserialize(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::Predictor;
    use crate::train::{TrainConfig, Trainer};

    fn trained() -> (TrainedModel, crate::data::Dataset) {
        let ds = SyntheticSpec::multiclass(600, 400, 24).seed(61).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        (tr.into_model(), ds)
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        let (m, ds) = trained();
        let bytes = serialize(&m);
        let m2 = deserialize(&bytes).unwrap();
        assert_eq!(m2.trellis.c, m.trellis.c);
        assert_eq!(m2.model.w, m.model.w);
        for i in 0..50 {
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let (m, _) = trained();
        let path = std::env::temp_dir().join("ltls_model_io_test.bin");
        save(&m, &path).unwrap();
        let m2 = load(&path).unwrap();
        assert_eq!(m2.model.bias, m.model.bias);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_corrupt_files() {
        let (m, _) = trained();
        let mut bytes = serialize(&m);
        assert!(deserialize(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(deserialize(&bytes).is_err()); // bad magic
        let (m2, _) = trained();
        let mut ok = serialize(&m2);
        ok.push(0); // trailing garbage
        assert!(deserialize(&ok).is_err());
    }
}
