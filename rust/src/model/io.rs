//! Model persistence: save / load a trained LTLS model (weights + trellis
//! + label↔path assignment) as a single self-describing binary file, so
//! `ltls train` can hand a model to `ltls serve` / `ltls eval` across
//! processes — plus the epoch-boundary training **checkpoint** format used
//! by [`crate::train::ParallelTrainer`] for crash-safe resume.
//!
//! Model format (little-endian):
//! ```text
//! magic "LTLS" | version u32 | C u64 | width u32 | D u64 | E u64 | n_labels u64
//! bias  [E f32] | weights [D*E f32, feature-major]
//! n_pairs u64 | (label u32, path u64) * n_pairs
//! ```
//!
//! Version 2 added the `width u32` field (the W-LTLS trellis width);
//! version-1 files have no width field and load as width 2. The loader is
//! generic over [`Topology`] — `deserialize::<Trellis>` rejects wide
//! files, `deserialize::<WideTrellis>` accepts any width — and
//! [`load_any`] dispatches on the stored width for callers (the CLI) that
//! learn the topology from the file.
//!
//! Checkpoint format (little-endian, versioned independently):
//! ```text
//! magic "LTCK" | version u32 | epoch u32 | step u64 | seed u64
//! n_history u64 | (examples u64, active_hinge u64,
//!                  loss_sum f64-bits, new_labels u64) * n_history
//! model_len u64 | model bytes (the "LTLS" format above, raw weights)
//! ```
//!
//! A checkpoint stores the *raw* (unaveraged, un-thresholded) weights plus
//! the global SGD step, so a resumed run continues the lr schedule and the
//! per-epoch shuffles exactly. Not stored (restarts fresh at resume): the
//! weight-averager state and the assigner's random-fallback RNG.

use crate::assign::{AssignPolicy, Assigner};
use crate::graph::{Topology, Trellis, WideTrellis};
use crate::model::LinearEdgeModel;
use crate::train::metrics::EpochMetrics;
use crate::train::TrainedModel;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"LTLS";
/// v1: no width field (implicitly 2). v2: width u32 after C.
const VERSION: u32 = 2;
const CKPT_MAGIC: &[u8; 4] = b"LTCK";
const CKPT_VERSION: u32 = 1;

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.i + n > self.b.len() {
            return Err(format!("truncated model file at byte {}", self.i));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>, String> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

/// Serialize a trained model (any topology; the file records the width).
pub fn serialize<T: Topology>(m: &TrainedModel<T>) -> Vec<u8> {
    serialize_parts(&m.trellis, &m.model, &m.assigner)
}

/// Borrowing variant of [`serialize`]: write a model straight from live
/// trainer state, without assembling (or cloning into) a `TrainedModel`.
pub fn serialize_parts<T: Topology>(
    trellis: &T,
    model: &LinearEdgeModel,
    assigner: &Assigner,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + model.w.len() * 4);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u64(&mut out, trellis.c());
    put_u32(&mut out, trellis.width());
    put_u64(&mut out, model.n_features as u64);
    put_u64(&mut out, model.n_edges as u64);
    let pairs: Vec<(u32, u64)> = assigner.table.pairs().collect();
    let n_labels = pairs.iter().map(|&(l, _)| l as u64 + 1).max().unwrap_or(0);
    put_u64(&mut out, n_labels);
    for &b in &model.bias {
        out.extend_from_slice(&b.to_le_bytes());
    }
    for &w in &model.w {
        out.extend_from_slice(&w.to_le_bytes());
    }
    put_u64(&mut out, pairs.len() as u64);
    for (l, p) in pairs {
        put_u32(&mut out, l);
        put_u64(&mut out, p);
    }
    out
}

/// Deserialize a trained model as topology `T`. Errors if the file's
/// stored width is one `T` cannot represent (e.g. a wide file into
/// `TrainedModel<Trellis>`); use [`deserialize_any`] to dispatch on the
/// stored width instead.
pub fn deserialize<T: Topology>(bytes: &[u8]) -> Result<TrainedModel<T>, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        return Err("not an LTLS model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version == 0 || version > VERSION {
        return Err(format!("unsupported model version {version}"));
    }
    let c = r.u64()?;
    let width = if version >= 2 { r.u32()? } else { 2 };
    let d = r.u64()? as usize;
    let e = r.u64()? as usize;
    let n_labels = r.u64()? as usize;
    let trellis = T::build(c, width)?;
    if trellis.num_edges() != e {
        return Err(format!("edge count mismatch: file {e}, trellis {}", trellis.num_edges()));
    }
    let bias = r.f32s(e)?;
    let w = r.f32s(d * e)?;
    let mut model = LinearEdgeModel::new(e, d);
    model.bias = bias;
    model.w = w;
    let mut assigner = Assigner::new(AssignPolicy::Identity, n_labels.max(1), &trellis, 0);
    let n_pairs = r.u64()? as usize;
    for _ in 0..n_pairs {
        let l = r.u32()?;
        let p = r.u64()?;
        assigner.table.bind(l, p);
    }
    if r.i != bytes.len() {
        return Err(format!("{} trailing bytes", bytes.len() - r.i));
    }
    Ok(TrainedModel { trellis, model, assigner })
}

/// Save to a file.
pub fn save<T: Topology>(m: &TrainedModel<T>, path: &Path) -> Result<(), String> {
    let bytes = serialize(m);
    let mut f = std::fs::File::create(path).map_err(|e| format!("{}: {e}", path.display()))?;
    f.write_all(&bytes).map_err(|e| e.to_string())
}

/// Load from a file as topology `T`.
pub fn load<T: Topology>(path: &Path) -> Result<TrainedModel<T>, String> {
    let mut bytes = Vec::new();
    std::fs::File::open(path)
        .map_err(|e| format!("{}: {e}", path.display()))?
        .read_to_end(&mut bytes)
        .map_err(|e| e.to_string())?;
    deserialize(&bytes)
}

/// A loaded model whose topology was chosen by the file's stored width:
/// width 2 gets the canonical [`Trellis`] (register-specialized decode
/// kernels), anything else a [`WideTrellis`]. This is how the CLI serves
/// and evaluates model files of any width.
pub enum AnyModel {
    Binary(TrainedModel<Trellis>),
    Wide(TrainedModel<WideTrellis>),
}

impl AnyModel {
    /// Number of classes.
    pub fn c(&self) -> u64 {
        match self {
            AnyModel::Binary(m) => m.trellis.c(),
            AnyModel::Wide(m) => m.trellis.c(),
        }
    }

    /// Trellis width.
    pub fn width(&self) -> u32 {
        match self {
            AnyModel::Binary(m) => m.trellis.width(),
            AnyModel::Wide(m) => m.trellis.width(),
        }
    }

    /// Number of learnable edges.
    pub fn num_edges(&self) -> usize {
        match self {
            AnyModel::Binary(m) => m.trellis.num_edges(),
            AnyModel::Wide(m) => m.trellis.num_edges(),
        }
    }
}

/// Peek a model file's header: `(C, width)` without building anything.
pub fn peek_meta(bytes: &[u8]) -> Result<(u64, u32), String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != MAGIC {
        return Err("not an LTLS model file (bad magic)".into());
    }
    let version = r.u32()?;
    if version == 0 || version > VERSION {
        return Err(format!("unsupported model version {version}"));
    }
    let c = r.u64()?;
    let width = if version >= 2 { r.u32()? } else { 2 };
    Ok((c, width))
}

/// Deserialize dispatching on the stored width (see [`AnyModel`]).
pub fn deserialize_any(bytes: &[u8]) -> Result<AnyModel, String> {
    let (_, width) = peek_meta(bytes)?;
    if width == 2 {
        Ok(AnyModel::Binary(deserialize::<Trellis>(bytes)?))
    } else {
        Ok(AnyModel::Wide(deserialize::<WideTrellis>(bytes)?))
    }
}

/// Load from a file dispatching on the stored width (see [`AnyModel`]).
pub fn load_any(path: &Path) -> Result<AnyModel, String> {
    let bytes = std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    deserialize_any(&bytes)
}

/// An epoch-boundary training checkpoint (see the module docs for the
/// on-disk format and what is / is not restored). Generic over the
/// topology — the embedded model bytes carry the width.
#[derive(Clone)]
pub struct Checkpoint<T: Topology = Trellis> {
    /// Epochs completed when this checkpoint was taken.
    pub epoch: u32,
    /// Global SGD step (examples seen), driving the lr schedule and the
    /// per-epoch shuffle salts.
    pub step: u64,
    /// The training seed (sanity: resume with the same-seeded config).
    pub seed: u64,
    /// Per-epoch metrics, oldest first.
    pub history: Vec<EpochMetrics>,
    /// Raw (unaveraged) weights + trellis + label↔path table.
    pub model: TrainedModel<T>,
}

/// Serialize a checkpoint.
pub fn serialize_checkpoint<T: Topology>(ck: &Checkpoint<T>) -> Vec<u8> {
    serialize_checkpoint_with(ck.epoch, ck.step, ck.seed, &ck.history, &serialize(&ck.model))
}

/// Low-level checkpoint writer over pre-serialized model bytes. Combined
/// with [`serialize_parts`] this lets the trainer checkpoint every epoch
/// without cloning its weight matrix into a temporary `TrainedModel`.
pub fn serialize_checkpoint_with(
    epoch: u32,
    step: u64,
    seed: u64,
    history: &[EpochMetrics],
    model_bytes: &[u8],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(model_bytes.len() + 64 + history.len() * 32);
    out.extend_from_slice(CKPT_MAGIC);
    put_u32(&mut out, CKPT_VERSION);
    put_u32(&mut out, epoch);
    put_u64(&mut out, step);
    put_u64(&mut out, seed);
    put_u64(&mut out, history.len() as u64);
    for m in history {
        put_u64(&mut out, m.examples);
        put_u64(&mut out, m.active_hinge);
        put_u64(&mut out, m.loss_sum.to_bits());
        put_u64(&mut out, m.new_labels);
    }
    put_u64(&mut out, model_bytes.len() as u64);
    out.extend_from_slice(model_bytes);
    out
}

/// Deserialize a checkpoint as topology `T` (errors if the embedded model
/// was trained at a width `T` cannot represent).
pub fn deserialize_checkpoint<T: Topology>(bytes: &[u8]) -> Result<Checkpoint<T>, String> {
    let mut r = Reader { b: bytes, i: 0 };
    if r.take(4)? != CKPT_MAGIC {
        return Err("not an LTLS checkpoint file (bad magic)".into());
    }
    let version = r.u32()?;
    if version != CKPT_VERSION {
        return Err(format!("unsupported checkpoint version {version}"));
    }
    let epoch = r.u32()?;
    let step = r.u64()?;
    let seed = r.u64()?;
    let n_history = r.u64()? as usize;
    if n_history.saturating_mul(32) > bytes.len() {
        return Err("truncated checkpoint (history)".into());
    }
    let mut history = Vec::with_capacity(n_history);
    for _ in 0..n_history {
        let examples = r.u64()?;
        let active_hinge = r.u64()?;
        let loss_sum = f64::from_bits(r.u64()?);
        let new_labels = r.u64()?;
        history.push(EpochMetrics { examples, active_hinge, loss_sum, new_labels });
    }
    let model_len = r.u64()? as usize;
    let model = deserialize(r.take(model_len)?)?;
    if r.i != bytes.len() {
        return Err(format!("{} trailing bytes", bytes.len() - r.i));
    }
    Ok(Checkpoint { epoch, step, seed, history, model })
}

/// Save a checkpoint, atomically: write to `<path>.tmp`, then rename, so a
/// crash mid-write never clobbers the previous checkpoint.
pub fn save_checkpoint<T: Topology>(ck: &Checkpoint<T>, path: &Path) -> Result<(), String> {
    write_atomic(&serialize_checkpoint(ck), path)
}

/// Atomic file replace (`<path>.tmp` + rename).
pub fn write_atomic(bytes: &[u8], path: &Path) -> Result<(), String> {
    let tmp = path.with_extension("ltck.tmp");
    std::fs::write(&tmp, bytes).map_err(|e| format!("{}: {e}", tmp.display()))?;
    std::fs::rename(&tmp, path).map_err(|e| format!("{}: {e}", path.display()))
}

/// Load a checkpoint from a file as topology `T`.
pub fn load_checkpoint<T: Topology>(path: &Path) -> Result<Checkpoint<T>, String> {
    let bytes =
        std::fs::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
    deserialize_checkpoint(&bytes)
}

/// Canonical checkpoint file name for an epoch: `dir/epoch-NNNN.ltck`.
pub fn checkpoint_path(dir: &Path, epoch: u32) -> PathBuf {
    dir.join(format!("epoch-{epoch:04}.ltck"))
}

/// Delete every `epoch-NNNN.ltck` (and stray `.ltck.tmp`) in `dir`;
/// returns how many files were removed. A *fresh* training run pointed at
/// a dir that still holds an older run's checkpoints must clear them,
/// otherwise a later `--resume` would pick up the stale run's
/// higher-numbered epochs instead of the new run's.
pub fn clear_checkpoints(dir: &Path) -> Result<usize, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut removed = 0usize;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let is_ckpt = name
            .strip_prefix("epoch-")
            .and_then(|s| s.strip_suffix(".ltck").or_else(|| s.strip_suffix(".ltck.tmp")))
            .map(|num| num.parse::<u32>().is_ok())
            .unwrap_or(false);
        if is_ckpt {
            std::fs::remove_file(entry.path())
                .map_err(|e| format!("{}: {e}", entry.path().display()))?;
            removed += 1;
        }
    }
    Ok(removed)
}

/// The highest-epoch `epoch-NNNN.ltck` in `dir`, if any.
pub fn latest_checkpoint(dir: &Path) -> Result<Option<(u32, PathBuf)>, String> {
    let entries = std::fs::read_dir(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    let mut best: Option<(u32, PathBuf)> = None;
    for entry in entries {
        let entry = entry.map_err(|e| e.to_string())?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(num) = name.strip_prefix("epoch-").and_then(|s| s.strip_suffix(".ltck")) else {
            continue;
        };
        let Ok(epoch) = num.parse::<u32>() else { continue };
        if best.as_ref().map(|(b, _)| epoch > *b).unwrap_or(true) {
            best = Some((epoch, entry.path()));
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;
    use crate::eval::Predictor;
    use crate::train::{TrainConfig, Trainer};

    fn trained() -> (TrainedModel, crate::data::Dataset) {
        let ds = SyntheticSpec::multiclass(600, 400, 24).seed(61).generate();
        let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
        tr.fit(&ds, 3);
        (tr.into_model(), ds)
    }

    #[test]
    fn roundtrip_preserves_predictions() {
        let (m, ds) = trained();
        let bytes = serialize(&m);
        let m2 = deserialize::<Trellis>(&bytes).unwrap();
        assert_eq!(m2.trellis.c, m.trellis.c);
        assert_eq!(m2.model.w, m.model.w);
        for i in 0..50 {
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let (m, _) = trained();
        let path = std::env::temp_dir().join("ltls_model_io_test.bin");
        save(&m, &path).unwrap();
        let m2 = load::<Trellis>(&path).unwrap();
        assert_eq!(m2.model.bias, m.model.bias);
        std::fs::remove_file(&path).ok();
    }

    /// A wide model round-trips: the file carries its width, `load_any`
    /// dispatches on it, and `deserialize::<Trellis>` rejects it.
    #[test]
    fn wide_model_roundtrip_and_dispatch() {
        let ds = SyntheticSpec::multiclass(500, 300, 24).seed(62).generate();
        let cfg = TrainConfig { width: 4, ..TrainConfig::default() };
        let mut tr = crate::train::Trainer::<crate::graph::WideTrellis>::with_topology(
            cfg,
            ds.n_features,
            ds.n_labels,
        )
        .unwrap();
        tr.fit(&ds, 2);
        let m = tr.into_model();
        let bytes = serialize(&m);
        assert_eq!(peek_meta(&bytes).unwrap(), (24, 4));

        let m2 = deserialize::<WideTrellis>(&bytes).unwrap();
        assert_eq!(m2.model.w, m.model.w);
        for i in 0..30 {
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
        match deserialize_any(&bytes).unwrap() {
            AnyModel::Wide(w) => assert_eq!(w.trellis.width(), 4),
            AnyModel::Binary(_) => panic!("width-4 file dispatched to the binary trellis"),
        }
        let err = deserialize::<Trellis>(&bytes).unwrap_err();
        assert!(err.contains("width"), "{err}");
        // Width-2 files still dispatch to the specialized Trellis.
        let (m2w, _) = trained();
        match deserialize_any(&serialize(&m2w)).unwrap() {
            AnyModel::Binary(b) => assert_eq!(b.trellis.width(), 2),
            AnyModel::Wide(_) => panic!("width-2 file dispatched wide"),
        }
    }

    /// Version-1 files (no width field) still load, as width 2.
    #[test]
    fn version1_files_load_as_width_two() {
        let (m, ds) = trained();
        let v2 = serialize(&m);
        // Rewrite the header to v1: patch the version field and remove the
        // width u32 at bytes 16..20 (after magic+version+C).
        let mut v1 = Vec::with_capacity(v2.len() - 4);
        v1.extend_from_slice(&v2[..4]);
        v1.extend_from_slice(&1u32.to_le_bytes());
        v1.extend_from_slice(&v2[8..16]);
        v1.extend_from_slice(&v2[20..]);
        assert_eq!(peek_meta(&v1).unwrap(), (m.trellis.c, 2));
        let m2 = deserialize::<Trellis>(&v1).unwrap();
        assert_eq!(m2.model.w, m.model.w);
        for i in 0..20 {
            assert_eq!(m.topk(ds.row(i), 3), m2.topk(ds.row(i), 3), "row {i}");
        }
    }

    #[test]
    fn checkpoint_roundtrip() {
        let (m, _) = trained();
        let ck = Checkpoint {
            epoch: 3,
            step: 1234,
            seed: 42,
            history: vec![
                EpochMetrics { examples: 400, active_hinge: 300, loss_sum: 99.5, new_labels: 24 },
                EpochMetrics { examples: 400, active_hinge: 120, loss_sum: 31.25, new_labels: 0 },
            ],
            model: m,
        };
        let bytes = serialize_checkpoint(&ck);
        let ck2 = deserialize_checkpoint::<Trellis>(&bytes).unwrap();
        assert_eq!(ck2.epoch, 3);
        assert_eq!(ck2.step, 1234);
        assert_eq!(ck2.seed, 42);
        assert_eq!(ck2.history.len(), 2);
        assert_eq!(ck2.history[0].examples, 400);
        assert_eq!(ck2.history[1].loss_sum, 31.25);
        assert_eq!(ck2.model.model.w, ck.model.model.w);
        assert_eq!(ck2.model.model.bias, ck.model.model.bias);
        // The embedded assignment table round-trips.
        let a: Vec<_> = ck.model.assigner.table.pairs().collect();
        let b: Vec<_> = ck2.model.assigner.table.pairs().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn checkpoint_rejects_corrupt_and_foreign_files() {
        let (m, _) = trained();
        let ck = Checkpoint { epoch: 1, step: 10, seed: 7, history: vec![], model: m };
        let mut bytes = serialize_checkpoint(&ck);
        assert!(deserialize_checkpoint::<Trellis>(&bytes[..16]).is_err()); // truncated
        bytes.push(0);
        assert!(deserialize_checkpoint::<Trellis>(&bytes).is_err()); // trailing garbage
        bytes.pop();
        bytes[0] = b'X';
        assert!(deserialize_checkpoint::<Trellis>(&bytes).is_err()); // bad magic
        // A plain model file is not a checkpoint (and vice versa).
        let (m2, _) = trained();
        assert!(deserialize_checkpoint::<Trellis>(&serialize(&m2)).is_err());
        let ck2 = Checkpoint { epoch: 1, step: 10, seed: 7, history: vec![], model: m2 };
        assert!(deserialize::<Trellis>(&serialize_checkpoint(&ck2)).is_err());
    }

    #[test]
    fn checkpoint_dir_save_load_latest() {
        let dir = std::env::temp_dir().join(format!("ltls_ckpt_io_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (m, _) = trained();
        for epoch in [1u32, 2, 10] {
            let ck = Checkpoint {
                epoch,
                step: epoch as u64 * 100,
                seed: 42,
                history: vec![],
                model: m.clone(),
            };
            save_checkpoint(&ck, &checkpoint_path(&dir, epoch)).unwrap();
        }
        let (epoch, path) = latest_checkpoint(&dir).unwrap().expect("checkpoints exist");
        assert_eq!(epoch, 10);
        let ck = load_checkpoint::<Trellis>(&path).unwrap();
        assert_eq!(ck.epoch, 10);
        assert_eq!(ck.step, 1000);
        // No tmp files left behind by the atomic writes.
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(".tmp"))
            .collect();
        assert!(leftovers.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn latest_checkpoint_empty_dir_is_none() {
        let dir = std::env::temp_dir().join(format!("ltls_ckpt_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(latest_checkpoint(&dir).unwrap().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn clear_checkpoints_removes_only_checkpoint_files() {
        let dir = std::env::temp_dir().join(format!("ltls_ckpt_clear_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("epoch-0001.ltck"), b"x").unwrap();
        std::fs::write(dir.join("epoch-0007.ltck.tmp"), b"x").unwrap();
        std::fs::write(dir.join("notes.txt"), b"keep me").unwrap();
        std::fs::write(dir.join("epoch-abc.ltck"), b"keep me too").unwrap();
        assert_eq!(clear_checkpoints(&dir).unwrap(), 2);
        assert!(latest_checkpoint(&dir).unwrap().is_none());
        assert!(dir.join("notes.txt").exists());
        assert!(dir.join("epoch-abc.ltck").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_corrupt_files() {
        let (m, _) = trained();
        let mut bytes = serialize(&m);
        assert!(deserialize::<Trellis>(&bytes[..10]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(deserialize::<Trellis>(&bytes).is_err()); // bad magic
        let (m2, _) = trained();
        let mut ok = serialize(&m2);
        ok.push(0); // trailing garbage
        assert!(deserialize::<Trellis>(&ok).is_err());
    }
}
