//! Underlying models for edge scores `h(w, x)` (paper §4.1).
//!
//! The basic model is one linear scorer per edge, `W ∈ R^{E×D}` — the
//! model is then the low-rank factorization `f = M_G · W · x`. Training is
//! sparse averaged SGD (§5): an update touches only the edges in the
//! symmetric difference of two paths and only the active features of `x`.
//!
//! The deep variant (the ImageNet fix of §6) lives in `python/compile` and
//! is executed via [`crate::runtime`]; this module also hosts the L1
//! soft-thresholding predictor of §6.

pub mod averaged;
pub mod io;
pub mod l1;
pub mod linear;

pub use io::Checkpoint;
pub use linear::LinearEdgeModel;
