//! Underlying models for edge scores `h(w, x)` (paper §4.1).
//!
//! The basic model is one linear scorer per edge, `W ∈ R^{E×D}` — the
//! model is then the low-rank factorization `f = M_G · W · x`. Training is
//! sparse averaged SGD (§5): an update touches only the edges in the
//! symmetric difference of two paths and only the active features of `x`.
//!
//! Weight **storage** is pluggable behind the [`store::WeightStore`] /
//! [`store::TrainableStore`] traits (see [`store`]): the default
//! [`linear::DenseStore`] is the paper's exact `D×E` f32 matrix, the
//! [`hashed::HashedStore`] bounds memory independently of `D` by signed
//! feature hashing, and the serve-only [`quant::Q8Store`] holds a trained
//! dense model as per-edge-scaled i8. Model files (format v3, [`io`])
//! carry the backend tag and can be served zero-copy from an mmap
//! ([`mmap`]).
//!
//! The deep variant (the ImageNet fix of §6) lives in `python/compile`
//! and is executed via [`crate::runtime`]; this module also hosts the L1
//! soft-thresholding predictor of §6.

pub mod averaged;
pub mod hashed;
pub mod io;
pub mod l1;
pub mod linear;
pub mod mmap;
pub mod quant;
pub mod shard;
pub mod store;

pub use hashed::HashedStore;
pub use io::Checkpoint;
pub use linear::{DenseStore, LinearEdgeModel};
pub use quant::Q8Store;
pub use shard::{slice_model, slice_store, ShardStore};
pub use store::{Backend, ScoreScratch, StripCodec, TrainableStore, WeightStore};
