//! Label-space shard slices of a trained model: a [`ShardStore`] wraps a
//! column slice of any [`WeightStore`] backend and presents it at the
//! **full** edge width, with the terminal edges of foreign shards pinned
//! to `−∞`.
//!
//! The point is exactness, not approximation. The list-Viterbi decoders
//! add terminal-edge scores only at emission (see
//! [`crate::graph::shardmap`]), so a decoder running over this store
//! produces the global top-k *restricted to the shard's labels*, with
//! scores bit-identical to the single-process model — every owned edge's
//! weights and bias are untouched copies, and every body-edge computation
//! happens in the same order over the same column subset? No: body edges
//! are **owned by every shard**, so the inner store holds all of them and
//! the per-edge dot products are the very same `Σ x_i·w[i,e] + b_e` sums.
//! Masked foreign candidates sort after every finite candidate and are
//! dropped by [`crate::train::TrainedModel::resolve_topk`]'s finite-score
//! cutoff.
//!
//! A slice is built offline by [`slice_model`] (the `ltls shard`
//! subcommand) from a [`ShardPlan`], persisted as a **v4** model file
//! ([`crate::model::io::serialize_shard`]) and loaded back — mmap
//! included — through the ordinary [`crate::model::io::load_any`] path.

use super::store::{Backend, ScoreScratch, WeightBlock, WeightStore};
use crate::graph::{ShardPlan, Topology};
use crate::sparse::SparseVec;
use crate::train::TrainedModel;
use std::sync::Arc;

/// A column slice of a weight store, re-widened to the full edge space
/// with foreign terminal edges at `−∞`.
#[derive(Clone)]
pub struct ShardStore<S: WeightStore> {
    /// The sliced store: `owned.len()` columns of the full model.
    inner: S,
    /// Ascending full-model edge indices the slice owns.
    owned: Arc<Vec<u32>>,
    /// Full-width score template: the inner bias at owned positions, `−∞`
    /// at foreign terminal edges. Doubles as [`WeightStore::bias`], so a
    /// bias-only score (empty input) is already correctly masked.
    template: Arc<Vec<f32>>,
    shard_id: u32,
    n_shards: u32,
}

impl<S: WeightStore> std::fmt::Debug for ShardStore<S> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ShardStore")
            .field("backend", &S::BACKEND.name())
            .field("shard_id", &self.shard_id)
            .field("n_shards", &self.n_shards)
            .field("owned_edges", &self.owned.len())
            .field("full_edges", &self.template.len())
            .finish()
    }
}

impl<S: WeightStore> ShardStore<S> {
    /// Assemble a shard store from its parts, validating the invariants a
    /// v4 file cannot be trusted to uphold.
    pub fn from_parts(
        inner: S,
        owned: Vec<u32>,
        full_edges: usize,
        shard_id: u32,
        n_shards: u32,
    ) -> Result<ShardStore<S>, String> {
        if n_shards == 0 || shard_id >= n_shards {
            return Err(format!("shard id {shard_id} out of range (n_shards {n_shards})"));
        }
        if owned.is_empty() || owned.len() > full_edges {
            return Err(format!(
                "shard owns {} of {full_edges} edges — corrupt slice",
                owned.len()
            ));
        }
        if !owned.windows(2).all(|w| w[0] < w[1]) || owned.last().map(|&e| e as usize >= full_edges) == Some(true)
        {
            return Err("shard owned-edge list is not strictly ascending in range".into());
        }
        if inner.n_edges() != owned.len() {
            return Err(format!(
                "sliced store has {} edges, owned list {}",
                inner.n_edges(),
                owned.len()
            ));
        }
        let mut template = vec![f32::NEG_INFINITY; full_edges];
        for (j, &e) in owned.iter().enumerate() {
            template[e as usize] = inner.bias()[j];
        }
        Ok(ShardStore {
            inner,
            owned: Arc::new(owned),
            template: Arc::new(template),
            shard_id,
            n_shards,
        })
    }

    /// The sliced inner store.
    pub fn inner(&self) -> &S {
        &self.inner
    }

    /// Ascending full-model edge indices this shard owns.
    pub fn owned_edges(&self) -> &[u32] {
        &self.owned
    }

    pub fn shard_id(&self) -> u32 {
        self.shard_id
    }

    pub fn n_shards(&self) -> u32 {
        self.n_shards
    }

    /// Scatter one row of inner scores over the masked template.
    #[inline]
    fn widen(&self, partial: &[f32], out: &mut Vec<f32>) {
        let base = out.len();
        out.extend_from_slice(&self.template);
        let row = &mut out[base..];
        for (j, &e) in self.owned.iter().enumerate() {
            row[e as usize] = partial[j];
        }
    }
}

impl<S: WeightStore> WeightStore for ShardStore<S> {
    const BACKEND: Backend = S::BACKEND;

    /// The **full** model's edge count: decoders see the whole graph.
    fn n_edges(&self) -> usize {
        self.template.len()
    }
    fn n_features(&self) -> usize {
        self.inner.n_features()
    }
    fn bias(&self) -> &[f32] {
        &self.template
    }

    fn edge_scores(&self, x: SparseVec, scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        let mut partial = std::mem::take(&mut scratch.partial);
        self.inner.edge_scores(x, scratch, &mut partial);
        out.clear();
        self.widen(&partial, out);
        scratch.partial = partial;
    }

    fn edge_scores_batch(&self, rows: &[SparseVec], scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        let mut partial = std::mem::take(&mut scratch.partial);
        self.inner.edge_scores_batch(rows, scratch, &mut partial);
        let e_own = self.inner.n_edges();
        out.clear();
        out.reserve(rows.len() * self.template.len());
        for r in 0..rows.len() {
            self.widen(&partial[r * e_own..(r + 1) * e_own], out);
        }
        scratch.partial = partial;
    }

    fn param_count(&self) -> usize {
        self.inner.param_count()
    }
    fn bytes(&self) -> usize {
        self.inner.bytes()
    }
    fn weight_count(&self) -> usize {
        self.inner.weight_count()
    }
    fn weight_elem_bytes(&self) -> usize {
        self.inner.weight_elem_bytes()
    }
    fn zero_weights(&self) -> usize {
        self.inner.zero_weights()
    }
    fn shard_part(&self) -> Option<(u32, u32)> {
        Some((self.shard_id, self.n_shards))
    }
    fn is_mapped(&self) -> bool {
        self.inner.is_mapped()
    }

    fn write_meta(&self, out: &mut Vec<u8>) {
        self.inner.write_meta(out);
    }
    fn weight_block_len(&self) -> usize {
        self.inner.weight_block_len()
    }
    fn write_weights(&self, out: &mut Vec<u8>) {
        self.inner.write_weights(out);
    }
    fn read_store(
        _n_edges: usize,
        _n_features: usize,
        _meta: &[u8],
        _bias: Vec<f32>,
        _weights: WeightBlock<'_>,
    ) -> Result<Self, String> {
        Err("shard slices carry extra framing; load them with `load_any` (model format v4)".into())
    }
}

/// Column-slice any weight store to the `owned` edge subset (ascending
/// full-model edge indices): each weight row keeps the owned columns,
/// byte-for-byte; bias and per-edge metadata are sliced alongside.
pub fn slice_store<S: WeightStore>(full: &S, owned: &[u32]) -> Result<S, String> {
    let e_full = full.n_edges();
    let elem = full.weight_elem_bytes();
    let rows = full.weight_count() / e_full;
    debug_assert_eq!(rows * e_full, full.weight_count(), "non-rectangular weight block");
    let mut block = Vec::with_capacity(full.weight_block_len());
    full.write_weights(&mut block);
    if block.len() != rows * e_full * elem {
        return Err(format!(
            "weight block is {} bytes, expected {} — cannot column-slice this backend",
            block.len(),
            rows * e_full * elem
        ));
    }
    let row_bytes = e_full * elem;
    let mut sliced = Vec::with_capacity(rows * owned.len() * elem);
    for r in 0..rows {
        let row = &block[r * row_bytes..(r + 1) * row_bytes];
        for &c in owned {
            let c = c as usize * elem;
            sliced.extend_from_slice(&row[c..c + elem]);
        }
    }
    let bias: Vec<f32> = owned.iter().map(|&c| full.bias()[c as usize]).collect();
    let mut meta = Vec::new();
    full.slice_meta(owned, &mut meta);
    S::read_store(owned.len(), full.n_features(), &meta, bias, WeightBlock::Owned(&sliced))
}

/// Slice a trained model down to `shard`'s share of `plan`: the owned
/// weight columns plus the full label↔path table and topology, wrapped so
/// the ordinary decode stack scores it at full edge width.
pub fn slice_model<T: Topology, S: WeightStore>(
    m: &TrainedModel<T, S>,
    plan: &ShardPlan,
    shard: u32,
) -> Result<TrainedModel<T, ShardStore<S>>, String> {
    if let Some((id, n)) = m.model.shard_part() {
        return Err(format!("model is already shard {id}/{n}; slice the full model instead"));
    }
    if shard >= plan.n_shards() {
        return Err(format!("shard {shard} out of range (plan has {})", plan.n_shards()));
    }
    let owned = plan.owned_edges(shard);
    let inner = slice_store(&m.model, &owned)?;
    let store =
        ShardStore::from_parts(inner, owned, m.trellis.num_edges(), shard, plan.n_shards())?;
    Ok(TrainedModel { trellis: m.trellis.clone(), model: store, assigner: m.assigner.clone() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{ShardPlan, Trellis};
    use crate::model::linear::DenseStore;
    use crate::model::quant::Q8Store;
    use crate::util::rng::Rng;

    fn random_dense(e: usize, d: usize, seed: u64) -> DenseStore {
        let mut m = DenseStore::new(e, d);
        let mut rng = Rng::new(seed);
        for w in m.w.as_mut_slice() {
            *w = rng.normal() * 0.3;
        }
        for b in &mut m.bias {
            *b = rng.normal() * 0.05;
        }
        m
    }

    /// A sliced store scores exactly like the full store with foreign
    /// columns forced to −∞ — owned scores bit-identical, per-row and
    /// batched.
    #[test]
    fn sliced_scores_match_masked_full_scores() {
        let t = Trellis::new(105);
        let e = crate::graph::Topology::num_edges(&t);
        let full = random_dense(e, 40, 11);
        let plan = ShardPlan::new(&t, 2).unwrap();
        let mut rng = Rng::new(12);
        for shard in 0..2u32 {
            let owned = plan.owned_edges(shard);
            let inner = slice_store(&full, &owned).unwrap();
            let store = ShardStore::from_parts(inner, owned.clone(), e, shard, 2).unwrap();
            assert_eq!(store.n_edges(), e);
            assert_eq!(store.shard_part(), Some((shard, 2)));
            let rows: Vec<(Vec<u32>, Vec<f32>)> = (0..5)
                .map(|_| {
                    let mut idx: Vec<u32> = (0..8).map(|_| rng.index(40) as u32).collect();
                    idx.sort_unstable();
                    idx.dedup();
                    let val: Vec<f32> = idx.iter().map(|_| rng.normal()).collect();
                    (idx, val)
                })
                .collect();
            let views: Vec<SparseVec> =
                rows.iter().map(|(i, v)| SparseVec::new(i, v)).collect();
            let mut scratch = ScoreScratch::new();
            let (mut hs, mut hf) = (Vec::new(), Vec::new());
            for x in &views {
                store.edge_scores(*x, &mut scratch, &mut hs);
                full.edge_scores(*x, &mut hf);
                assert_eq!(hs.len(), e);
                let owned_set: std::collections::BTreeSet<u32> = owned.iter().copied().collect();
                for edge in 0..e {
                    if owned_set.contains(&(edge as u32)) {
                        assert_eq!(hs[edge].to_bits(), hf[edge].to_bits(), "edge {edge}");
                    } else {
                        assert_eq!(hs[edge], f32::NEG_INFINITY, "edge {edge}");
                    }
                }
            }
            // Batched path matches the per-row path bit-for-bit.
            let mut batch = Vec::new();
            store.edge_scores_batch(&views, &mut scratch, &mut batch);
            assert_eq!(batch.len(), views.len() * e);
            for (r, x) in views.iter().enumerate() {
                store.edge_scores(*x, &mut scratch, &mut hs);
                assert_eq!(&batch[r * e..(r + 1) * e], hs.as_slice(), "row {r}");
            }
        }
    }

    /// Q8 per-edge scales survive slicing (the `slice_meta` override).
    #[test]
    fn q8_slice_keeps_per_edge_scales() {
        let t = Trellis::new(159);
        let e = crate::graph::Topology::num_edges(&t);
        let dense = random_dense(e, 30, 21);
        let q8 = Q8Store::quantize(&dense);
        let plan = ShardPlan::new(&t, 3).unwrap();
        let owned = plan.owned_edges(1);
        let sliced = slice_store(&q8, &owned).unwrap();
        assert_eq!(sliced.n_edges, owned.len());
        for (j, &c) in owned.iter().enumerate() {
            assert_eq!(sliced.scale[j], q8.scale[c as usize]);
            assert_eq!(sliced.bias[j], q8.bias[c as usize]);
            for i in 0..30usize {
                assert_eq!(sliced.q[i * owned.len() + j], q8.q[i * e + c as usize]);
            }
        }
    }

    #[test]
    fn from_parts_validates() {
        let inner = random_dense(3, 4, 5);
        // Not ascending.
        assert!(ShardStore::from_parts(inner.clone(), vec![2, 1, 0], 10, 0, 2).is_err());
        // Out of range.
        assert!(ShardStore::from_parts(inner.clone(), vec![0, 1, 10], 10, 0, 2).is_err());
        // Shard id out of range.
        assert!(ShardStore::from_parts(inner.clone(), vec![0, 1, 2], 10, 2, 2).is_err());
        // Length mismatch against the inner store.
        assert!(ShardStore::from_parts(inner.clone(), vec![0, 1], 10, 0, 2).is_err());
        assert!(ShardStore::from_parts(inner, vec![0, 5, 9], 10, 1, 2).is_ok());
    }
}
