//! Signed feature hashing ("hashing trick", Weinberger et al. 2009) as a
//! [`WeightStore`] backend: feature `i`'s weight strip lives in bucket
//! `hash(i) mod 2^b`, and its value enters every score/update multiplied
//! by a pseudo-random sign `ξ(i) ∈ {−1, +1}`.
//!
//! Memory is `2^b · E` floats — **bounded independently of D** — so on
//! extreme datasets (D in the millions) the model shrinks by `D / 2^b`
//! while collisions act as mild regularizing noise; the sign hash makes
//! colliding contributions cancel in expectation instead of biasing
//! scores upward. The store is fully trainable: the serial and Hogwild
//! trainers drive it through the same [`StripCodec`] kernels as the dense
//! store (`ltls train --hash-bits b`), and checkpoints/model files carry
//! the `(bits, seed)` pair so resume and serving rebuild the identical
//! hash function.

use super::mmap::F32Buf;
use super::store::{
    codec_edge_scores, codec_edge_scores_batch, Backend, ScoreScratch, StripCodec, TrainableStore,
    WeightBlock, WeightStore,
};
use crate::sparse::SparseVec;

/// Valid `--hash-bits` range: below 4 every feature collides into a
/// handful of buckets; above 30 the table exceeds any dense model worth
/// hashing.
pub const MIN_HASH_BITS: u32 = 4;
pub const MAX_HASH_BITS: u32 = 30;

/// splitmix64 finalizer — full-avalanche 64-bit mix.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The feature → (bucket, sign) hash, shared by every kernel that touches
/// a hashed store (plain, batched, Hogwild-atomic, averaging).
#[derive(Clone, Copy, Debug)]
pub struct HashCodec {
    mask: u32,
    seed: u64,
}

impl HashCodec {
    pub fn new(bits: u32, seed: u64) -> HashCodec {
        debug_assert!((MIN_HASH_BITS..=MAX_HASH_BITS).contains(&bits));
        HashCodec { mask: (1u32 << bits) - 1, seed }
    }
}

impl StripCodec for HashCodec {
    #[inline]
    fn strip_of(&self, i: u32) -> (u32, f32) {
        let h = mix64(self.seed ^ (i as u64));
        // Low bits pick the bucket, the (independent) top bit the sign.
        let bucket = (h as u32) & self.mask;
        let sign = if h & (1 << 63) == 0 { 1.0 } else { -1.0 };
        (bucket, sign)
    }
}

/// Feature-hashed linear edge model: `2^bits` strips of `E` floats.
#[derive(Clone, Debug)]
pub struct HashedStore {
    pub n_edges: usize,
    /// Logical feature dimensionality `D` (what the dataset indexes with).
    pub n_features: usize,
    /// Bucket count exponent: `2^bits` physical strips.
    pub bits: u32,
    /// Hash seed (persisted — serving must rebuild the same function).
    pub seed: u64,
    /// Bucket-major `2^bits × E` weights.
    pub w: F32Buf,
    /// Per-edge bias.
    pub bias: Vec<f32>,
}

impl HashedStore {
    /// Zero-initialized hashed model.
    pub fn new(n_edges: usize, n_features: usize, bits: u32, seed: u64) -> Result<Self, String> {
        if !(MIN_HASH_BITS..=MAX_HASH_BITS).contains(&bits) {
            return Err(format!(
                "--hash-bits must be in {MIN_HASH_BITS}..={MAX_HASH_BITS}, got {bits}"
            ));
        }
        let strips = 1usize << bits;
        Ok(HashedStore {
            n_edges,
            n_features,
            bits,
            seed,
            w: F32Buf::from(vec![0.0; strips * n_edges]),
            bias: vec![0.0; n_edges],
        })
    }

    /// Dense-equivalent parameter count this store replaces (`E·D + E`) —
    /// the compression headline is `dense_params / param_count`.
    pub fn dense_equivalent_params(&self) -> usize {
        self.n_edges * self.n_features + self.n_edges
    }
}

impl WeightStore for HashedStore {
    const BACKEND: Backend = Backend::Hashed;

    fn n_edges(&self) -> usize {
        self.n_edges
    }
    fn n_features(&self) -> usize {
        self.n_features
    }
    fn bias(&self) -> &[f32] {
        &self.bias
    }
    fn edge_scores(&self, x: SparseVec, _scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        codec_edge_scores(&self.w, &self.bias, self.n_edges, self.codec(), x, out);
    }
    fn edge_scores_batch(&self, rows: &[SparseVec], scratch: &mut ScoreScratch, out: &mut Vec<f32>) {
        codec_edge_scores_batch(
            &self.w,
            &self.bias,
            self.n_edges,
            self.codec(),
            rows,
            &mut scratch.gather,
            out,
        );
    }
    fn param_count(&self) -> usize {
        self.w.len() + self.bias.len()
    }
    fn bytes(&self) -> usize {
        self.param_count() * std::mem::size_of::<f32>()
    }
    fn weight_count(&self) -> usize {
        self.w.len()
    }
    fn weight_elem_bytes(&self) -> usize {
        std::mem::size_of::<f32>()
    }
    fn zero_weights(&self) -> usize {
        self.w.iter().filter(|&&v| v == 0.0).count()
    }
    fn is_mapped(&self) -> bool {
        self.w.is_mapped()
    }

    fn write_meta(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.bits.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
    }
    fn weight_block_len(&self) -> usize {
        self.w.len() * 4
    }
    fn write_weights(&self, out: &mut Vec<u8>) {
        for &w in self.w.iter() {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
    fn read_store(
        n_edges: usize,
        n_features: usize,
        meta: &[u8],
        bias: Vec<f32>,
        weights: WeightBlock<'_>,
    ) -> Result<Self, String> {
        if meta.len() != 12 {
            return Err(format!("hashed model meta is {} bytes, expected 12", meta.len()));
        }
        let bits = u32::from_le_bytes(meta[0..4].try_into().unwrap());
        let seed = u64::from_le_bytes(meta[4..12].try_into().unwrap());
        if !(MIN_HASH_BITS..=MAX_HASH_BITS).contains(&bits) {
            return Err(format!("hashed model has invalid hash-bits {bits}"));
        }
        if bias.len() != n_edges {
            return Err(format!("bias is {} entries, expected {n_edges}", bias.len()));
        }
        let w = weights.into_f32((1usize << bits) * n_edges)?;
        Ok(HashedStore { n_edges, n_features, bits, seed, w, bias })
    }
}

impl TrainableStore for HashedStore {
    type Codec = HashCodec;

    fn codec(&self) -> HashCodec {
        HashCodec::new(self.bits, self.seed)
    }
    fn n_strips(&self) -> usize {
        1usize << self.bits
    }
    fn raw_w(&self) -> &[f32] {
        &self.w
    }
    fn raw_parts_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (self.w.as_mut_slice(), self.bias.as_mut_slice())
    }
    fn hash_bits(&self) -> u32 {
        self.bits
    }
    fn for_topology_cfg<T: crate::graph::Topology>(
        t: &T,
        n_features: usize,
        hash_bits: u32,
        seed: u64,
    ) -> Result<Self, String> {
        Self::new(t.num_edges(), n_features, hash_bits, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codec_is_deterministic_and_in_range() {
        let c = HashCodec::new(8, 42);
        for i in 0..10_000u32 {
            let (b1, s1) = c.strip_of(i);
            let (b2, s2) = c.strip_of(i);
            assert_eq!((b1, s1), (b2, s2));
            assert!(b1 < 256);
            assert!(s1 == 1.0 || s1 == -1.0);
        }
    }

    #[test]
    fn codec_spreads_buckets_and_signs() {
        let c = HashCodec::new(8, 7);
        let mut counts = [0usize; 256];
        let mut neg = 0usize;
        let n = 50_000u32;
        for i in 0..n {
            let (b, s) = c.strip_of(i);
            counts[b as usize] += 1;
            if s < 0.0 {
                neg += 1;
            }
        }
        // Every bucket used; occupancy within 3x of uniform.
        let expect = n as usize / 256;
        for (b, &cnt) in counts.iter().enumerate() {
            assert!(cnt > expect / 3 && cnt < expect * 3, "bucket {b}: {cnt}");
        }
        // Signs near-balanced.
        let frac = neg as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.02, "negative-sign fraction {frac}");
        // Different seeds give different functions.
        let c2 = HashCodec::new(8, 8);
        let same = (0..1000u32).filter(|&i| c.strip_of(i) == c2.strip_of(i)).count();
        assert!(same < 100, "{same}/1000 collisions across seeds");
    }

    #[test]
    fn scores_match_manual_signed_accumulation() {
        let mut m = HashedStore::new(4, 1000, 6, 3).unwrap();
        let idx = [5u32, 700, 999];
        let val = [1.0f32, -2.0, 0.5];
        let x = SparseVec::new(&idx, &val);
        m.update_edge(2, x, 0.5);
        let mut h = Vec::new();
        WeightStore::edge_scores(&m, x, &mut ScoreScratch::new(), &mut h);
        // Manual: h_e = bias_e + Σ_i sign_i·v_i · w[bucket_i·E + e].
        let codec = m.codec();
        let mut want = m.bias.clone();
        for (&i, &v) in idx.iter().zip(&val) {
            let (b, s) = codec.strip_of(i);
            for (e, w) in want.iter_mut().enumerate() {
                *w += (v * s) * m.w[b as usize * 4 + e];
            }
        }
        for (a, b) in h.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        // The self-product is positive regardless of signs: the update
        // wrote sign·v and the score reads sign·v again.
        assert!(h[2] > 0.0);
    }

    #[test]
    fn batch_matches_per_row_bitwise() {
        let mut m = HashedStore::new(6, 500, 5, 11).unwrap();
        let xa = SparseVec::new(&[0, 77, 499], &[1.0, 2.0, -1.0]);
        let xb = SparseVec::new(&[3, 77], &[0.5, -0.5]);
        m.update_edge(1, xa, 0.3);
        m.update_edges(&[0, 2], &[5], xb, -0.7);
        let rows = [xa, xb, SparseVec::new(&[], &[])];
        let (mut scratch, mut batch) = (ScoreScratch::new(), Vec::new());
        WeightStore::edge_scores_batch(&m, &rows, &mut scratch, &mut batch);
        for (r, x) in rows.iter().enumerate() {
            let mut single = Vec::new();
            WeightStore::edge_scores(&m, *x, &mut scratch, &mut single);
            assert_eq!(&batch[r * 6..(r + 1) * 6], single.as_slice(), "row {r}");
        }
    }

    #[test]
    fn memory_is_bounded_by_bits_not_d() {
        let small_d = HashedStore::new(10, 1_000, 8, 1).unwrap();
        let huge_d = HashedStore::new(10, 10_000_000, 8, 1).unwrap();
        assert_eq!(small_d.bytes(), huge_d.bytes());
        assert_eq!(huge_d.param_count(), 256 * 10 + 10);
        assert!(huge_d.dense_equivalent_params() > 100 * huge_d.param_count());
        assert_eq!(huge_d.hash_bits(), 8);
        assert_eq!(huge_d.backend(), Backend::Hashed);
    }

    #[test]
    fn rejects_out_of_range_bits() {
        assert!(HashedStore::new(4, 100, 3, 0).is_err());
        assert!(HashedStore::new(4, 100, 31, 0).is_err());
        assert!(HashedStore::new(4, 100, 4, 0).is_ok());
        assert!(HashedStore::new(4, 100, 30, 0).is_ok());
    }
}
