//! # LTLS — Log-time and Log-space Extreme Classification
//!
//! A production-grade reproduction of *"Log-time and Log-space Extreme
//! Classification"* (Jasinska & Karampatziakis, 2016). LTLS embeds a C-way
//! multiclass / multilabel problem into a structured-prediction problem over
//! a trellis DAG with exactly `C` source→sink paths and `E = O(log C)`
//! learnable edges; (list-)Viterbi dynamic programming gives top-1 / top-k
//! prediction in `O(k log k · log C)` with an `O(D log C)` model.
//!
//! The crate is organized in three layers:
//!
//! * **L3 (this crate)** — the full LTLS system: trellis graph construction
//!   ([`graph`]), dynamic-programming decoders ([`decode`]), sparse averaged
//!   SGD training with the separation ranking loss ([`model`], [`loss`],
//!   [`train`]), the online label→path assignment policy ([`assign`]),
//!   dataset substrates ([`data`]), every baseline the paper compares
//!   against ([`baselines`]), evaluation harnesses ([`eval`]), a PJRT
//!   runtime that executes AOT-compiled JAX/Pallas artifacts ([`runtime`]),
//!   and a batching multi-worker prediction server ([`coordinator`])
//!   with a std-only TCP frontend ([`coordinator::transport`]: newline
//!   protocol, bounded admission with backpressure, plaintext metrics,
//!   graceful drain) and hot model reload ([`coordinator::reload`]:
//!   epoch-counted atomic swap between micro-batches — `RELOAD` command
//!   or `--watch-model` file polling — with zero dropped requests).
//!   The serving stack is instrumented end to end by the observability
//!   layer ([`obs`]): a lock-free sharded metrics registry (relaxed
//!   atomics, log2 latency histograms with full Prometheus export) and
//!   request-lifecycle tracing ([`obs::trace`]: per-stage span
//!   timelines, `--trace-sample` sampling plus an always-on
//!   slow-request ring, dumped by the `TRACE` wire command).
//!   The graph layer is width-parameterized (W-LTLS): everything above it
//!   is generic over [`graph::Topology`], with the paper's width-2
//!   [`graph::Trellis`] as the default and [`graph::WideTrellis`] turning
//!   the accuracy/size tradeoff into a runtime dial (`--width`). Weight
//!   **storage** is the third dial ([`model::store`]): the training and
//!   serving stacks are generic over [`model::WeightStore`] /
//!   [`model::TrainableStore`] — dense (default), signed-feature-hashed
//!   (`--hash-bits`, memory bounded independently of D), and serve-only
//!   i8 quantization (`ltls quantize`), with zero-copy mmap serving of v3
//!   model files (`ltls serve --mmap`).
//! * **Inference engine** ([`engine`]) — the zero-allocation spine under
//!   all prediction consumers: reusable decode workspaces
//!   ([`engine::DecodeWorkspace`]) backing the `_into` decoder variants,
//!   per-worker prediction scratchpads ([`engine::PredictScratch`]), and
//!   batched edge scoring
//!   ([`model::LinearEdgeModel::edge_scores_batch`]). The serving
//!   coordinator, the evaluation/timing harnesses, and the benches all
//!   route through it; `rust/tests/engine_parity.rs` pins the engine paths
//!   bit-identical to the allocating ones.
//! * **L2 (python/compile, build time only)** — the deep edge-scorer (the
//!   paper's ImageNet fix) and its training step as JAX programs, lowered
//!   once to HLO text by `make artifacts`.
//! * **L1 (python/compile/kernels)** — Pallas kernels for the dense hot
//!   spots (tiled edge-score matmul, batched trellis Viterbi), lowered into
//!   the same HLO artifacts.
//!
//! Python never runs on the request path: the rust binary is self-contained
//! once `artifacts/` is built.

pub mod assign;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod decode;
pub mod engine;
pub mod eval;
pub mod graph;
pub mod kernel;
pub mod loss;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod sparse;
pub mod train;
pub mod util;

/// Crate version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
