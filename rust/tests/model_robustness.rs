//! Loader robustness: corrupt model files — truncated, bad-magic,
//! bit-flipped headers — must surface as `Err` through every load path
//! (`deserialize_any`, `load_any`, `load_any_mmap`), never as a panic or
//! an out-of-bounds read of the mapped region. This is what makes hot
//! reload safe: `--watch-model` can race a writer and observe a
//! half-written file, and the contract tested here is what guarantees the
//! old model stays live (`rust/src/coordinator/reload.rs` pins the
//! keep-old-model half; `rust/tests/serve_network.rs` pins it end-to-end
//! over TCP).
//!
//! Fixtures: `model_v2_truncated.ltls` (the committed v2 fixture cut mid
//! weight block) and `model_badmagic.ltls` (first magic byte flipped) are
//! checked in alongside the v1/v2 fixtures; v3 corruption is exercised
//! programmatically over *every* strict prefix of a freshly serialized
//! model, heap and mmap both.

use ltls::data::synthetic::SyntheticSpec;
use ltls::model::io::{deserialize_any, load_any, load_any_mmap, serialize};
use ltls::train::{TrainConfig, Trainer};

const FIXTURE_TRUNCATED: &[u8] = include_bytes!("fixtures/model_v2_truncated.ltls");
const FIXTURE_BADMAGIC: &[u8] = include_bytes!("fixtures/model_badmagic.ltls");

fn trained_bytes() -> Vec<u8> {
    let ds = SyntheticSpec::multiclass(300, 200, 12).seed(41).generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 2);
    serialize(&tr.into_model())
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("ltls_robust_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The checked-in corrupt fixtures error cleanly.
#[test]
fn corrupt_fixtures_error_cleanly() {
    let err = deserialize_any(FIXTURE_TRUNCATED).unwrap_err();
    assert!(err.contains("truncated"), "{err}");
    let err = deserialize_any(FIXTURE_BADMAGIC).unwrap_err();
    assert!(err.contains("magic"), "{err}");
}

/// Every strict prefix of a valid v3 file is rejected — no cut point
/// (header, meta, bias, pairs, alignment padding, weight block) panics or
/// loads.
#[test]
fn every_v3_prefix_is_rejected() {
    let bytes = trained_bytes();
    assert!(deserialize_any(&bytes).is_ok(), "the untruncated file must load");
    for len in 0..bytes.len() {
        let r = deserialize_any(&bytes[..len]);
        assert!(r.is_err(), "prefix of {len}/{} bytes unexpectedly loaded", bytes.len());
    }
    // Trailing garbage is rejected too.
    let mut long = bytes.clone();
    long.extend_from_slice(&[0u8; 7]);
    assert!(deserialize_any(&long).is_err());
}

/// Header-field corruption (magic, version, backend tag, hostile D) is
/// rejected; `load_any` from disk behaves identically to in-memory
/// deserialization.
#[test]
fn corrupt_headers_error_through_load_any() {
    let dir = tmp_dir("hdr");
    let bytes = trained_bytes();
    // v3 header layout: magic [0..4) | version [4..8) | C [8..16) |
    // width [16..20) | D [20..28) | E [28..36) | n_labels [36..44) |
    // backend [44..48).
    let mut badmagic = bytes.clone();
    badmagic[0] = b'X';
    let mut badversion = bytes.clone();
    badversion[4..8].copy_from_slice(&99u32.to_le_bytes());
    let mut badbackend = bytes.clone();
    badbackend[44] = 9;
    let mut hostile_d = bytes.clone();
    hostile_d[20..28].copy_from_slice(&u64::MAX.to_le_bytes());
    for (tag, bad) in [
        ("badmagic", badmagic),
        ("badversion", badversion),
        ("badbackend", badbackend),
        ("hostile_d", hostile_d),
    ] {
        assert!(deserialize_any(&bad).is_err(), "{tag}: loaded in memory");
        let p = dir.join(format!("{tag}.ltls"));
        std::fs::write(&p, &bad).unwrap();
        assert!(load_any(&p).is_err(), "{tag}: loaded from disk");
        assert!(load_any_mmap(&p).is_err(), "{tag}: loaded via mmap");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Corrupt label↔path pairs — the section that used to hit *panicking*
/// assignment-table asserts (out-of-range labels/paths, double binds,
/// label counts beyond C) — now surface as load errors.
#[test]
fn corrupt_assignment_pairs_error_instead_of_panicking() {
    let bytes = trained_bytes();
    // Hostile n_labels (header offset 36..44): more labels than paths.
    let mut bad = bytes.clone();
    bad[36..44].copy_from_slice(&1_000_000u64.to_le_bytes());
    let err = deserialize_any(&bad).unwrap_err();
    assert!(err.contains("exceed"), "{err}");

    // v3 dense layout: header 48 | meta_len u64 | bias e*4 | n_pairs u64
    // | pairs (label u32, path u64)* — so pair 0's label sits at 64+4e.
    let e = deserialize_any(&bytes).unwrap().num_edges();
    let pair0 = 64 + 4 * e;

    // Out-of-range label in pair 0.
    let mut bad = bytes.clone();
    bad[pair0..pair0 + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = deserialize_any(&bad).unwrap_err();
    assert!(err.contains("out of range"), "{err}");

    // Duplicate binding: overwrite pair 1 with a copy of pair 0.
    let mut bad = bytes.clone();
    let src: Vec<u8> = bad[pair0..pair0 + 12].to_vec();
    bad[pair0 + 12..pair0 + 24].copy_from_slice(&src);
    let err = deserialize_any(&bad).unwrap_err();
    assert!(err.contains("twice"), "{err}");
}

/// Truncated files on disk are rejected by the heap loader AND the
/// zero-copy mmap loader at representative cut points (including cuts
/// inside the 64-byte-aligned trailing weight block, where a stale
/// length field could otherwise map out of bounds).
#[test]
fn truncated_files_error_through_both_disk_loaders() {
    let dir = tmp_dir("trunc");
    let bytes = trained_bytes();
    let n = bytes.len();
    // Cut points: empty, mid-header, just after the header, mid-pairs,
    // one byte into the weight block, one byte short of EOF.
    for cut in [0usize, 10, 44, 60, n / 2, n * 3 / 4, n - 1] {
        let p = dir.join(format!("cut_{cut}.ltls"));
        std::fs::write(&p, &bytes[..cut]).unwrap();
        assert!(load_any(&p).is_err(), "heap loader accepted a {cut}-byte prefix");
        assert!(load_any_mmap(&p).is_err(), "mmap loader accepted a {cut}-byte prefix");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The CLI's serve path surfaces a corrupt `--model` as a clean error:
/// `load_any` is exactly what `ltls serve --model` calls, so this pins
/// the non-panic contract the binary relies on.
#[test]
fn missing_and_empty_files_error() {
    assert!(load_any(std::path::Path::new("/nonexistent/ltls.model")).is_err());
    assert!(load_any_mmap(std::path::Path::new("/nonexistent/ltls.model")).is_err());
    let dir = tmp_dir("empty");
    let p = dir.join("empty.ltls");
    std::fs::write(&p, b"").unwrap();
    assert!(load_any(&p).is_err());
    assert!(load_any_mmap(&p).is_err());
    std::fs::remove_dir_all(&dir).ok();
}
