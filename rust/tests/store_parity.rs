//! Pluggable weight-storage contracts:
//!
//! 1. **Back-compat** — the checked-in v1/v2 byte fixtures
//!    (`tests/fixtures/model_v{1,2}.ltls`, written by the pre-backend
//!    serializer's layout) still load as dense under the v3 reader, both
//!    heap and memory-mapped.
//! 2. **Hashed parity** — the hashed store rides the identical training
//!    pipeline: a 1-worker Hogwild epoch is bit-identical to the serial
//!    epoch, exactly as pinned for dense in `train_parallel.rs`.
//! 3. **Hashed persistence** — model files and checkpoints carry the
//!    backend tag: loads dispatch on it, mistyped loads refuse, resume
//!    checks `--hash-bits` like it checks seed and width.
//! 4. **Q8 serving** — quantized precision@1 stays within 0.5% of the f32
//!    model; q8 files round-trip; the batched server path over a q8 store
//!    matches inline prediction.
//! 5. **Mmap serving** — `load_any_mmap` borrows weights zero-copy and
//!    predicts identically to the heap loader, for every backend.

use ltls::assign::{AssignPolicy, Assigner};
use ltls::data::synthetic::{SyntheticSpec, TeacherKind};
use ltls::eval::{precision_at_1, Predictor};
use ltls::graph::Trellis;
use ltls::model::{io, DenseStore, HashedStore, LinearEdgeModel, TrainableStore, WeightStore};
use ltls::sparse::SparseVec;
use ltls::train::{ParallelTrainer, TrainConfig, TrainedModel, Trainer};

const FIXTURE_V1: &[u8] = include_bytes!("fixtures/model_v1.ltls");
const FIXTURE_V2: &[u8] = include_bytes!("fixtures/model_v2.ltls");

/// Rebuild the exact model the fixtures were generated from: C=6 trellis
/// (10 edges), D=5, deterministic hand-written updates, label l bound to
/// path (5l mod 6).
fn fixture_model() -> TrainedModel {
    let trellis = Trellis::new(6);
    let e = ltls::graph::Topology::num_edges(&trellis);
    assert_eq!(e, 10, "fixture recipe assumes the C=6 trellis has 10 edges");
    let mut model = LinearEdgeModel::new(e, 5);
    for edge in 0..e {
        let idx = [edge as u32 % 5];
        let val = [0.25 + edge as f32 * 0.125];
        model.update_edge(edge, SparseVec::new(&idx, &val), 1.0);
    }
    let mut assigner = Assigner::new(AssignPolicy::Identity, 6, &trellis, 0);
    for l in 0..6u32 {
        assigner.table.bind(l, (l as u64 * 5) % 6);
    }
    TrainedModel { trellis, model, assigner }
}

fn fixture_path(name: &str) -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

/// Contract 1: the committed v1/v2 fixtures load as dense through the v3
/// reader, bit-for-bit equal to the reference reconstruction.
#[test]
fn v1_v2_fixtures_load_as_dense_under_v3_reader() {
    let want = fixture_model();
    for (name, bytes, version_width) in
        [("v1", FIXTURE_V1, (6u64, 2u32)), ("v2", FIXTURE_V2, (6, 2))]
    {
        assert_eq!(io::peek_meta(bytes).unwrap(), version_width, "{name}");
        assert_eq!(io::peek_backend(bytes).unwrap(), ltls::model::Backend::Dense, "{name}");
        let got = io::deserialize::<Trellis, DenseStore>(bytes).unwrap();
        assert_eq!(got.model.w, want.model.w, "{name} weights");
        assert_eq!(got.model.bias, want.model.bias, "{name} bias");
        let gp: Vec<_> = got.assigner.table.pairs().collect();
        let wp: Vec<_> = want.assigner.table.pairs().collect();
        assert_eq!(gp, wp, "{name} pairs");
        // The width×backend dispatcher sends old files to the dense
        // binary-trellis variant.
        match io::deserialize_any(bytes).unwrap() {
            io::AnyModel::Binary(m) => {
                for x in [
                    SparseVec::new(&[0, 3], &[1.0, -1.0]),
                    SparseVec::new(&[1, 2, 4], &[0.5, 2.0, 0.25]),
                    SparseVec::new(&[], &[]),
                ] {
                    assert_eq!(m.predict_topk(x, 3), want.predict_topk(x, 3), "{name}");
                }
            }
            _ => panic!("{name} fixture dispatched to a non-dense variant"),
        }
        // Old layouts are dense-only: a hashed-typed load refuses.
        assert!(io::deserialize::<Trellis, HashedStore>(bytes).is_err(), "{name}");
    }
}

/// Contract 1b: old files also serve through the mmap loader (their f32
/// block is 4-byte aligned even without the v3 64-byte padding).
#[test]
fn v2_fixture_loads_memory_mapped() {
    let want = fixture_model();
    let loaded = io::load_any_mmap(&fixture_path("model_v2.ltls")).unwrap();
    assert!(loaded.is_mapped());
    assert_eq!(loaded.c(), 6);
    match loaded {
        io::AnyModel::Binary(m) => {
            assert!(m.model.is_mapped());
            let x = SparseVec::new(&[0, 4], &[2.0, -0.5]);
            assert_eq!(m.predict_topk(x, 4), want.predict_topk(x, 4));
        }
        _ => panic!("v2 fixture dispatched to a non-dense variant"),
    }
}

/// Re-serializing the fixture model as v3 preserves everything the v2
/// bytes carried (the upgrade path is lossless).
#[test]
fn fixture_model_upgrades_to_v3_losslessly() {
    let want = fixture_model();
    let v3 = io::serialize(&want);
    assert_ne!(v3.as_slice(), FIXTURE_V2, "v3 layout differs from v2 on disk");
    let got = io::deserialize::<Trellis, DenseStore>(&v3).unwrap();
    assert_eq!(got.model.w, want.model.w);
    assert_eq!(got.model.bias, want.model.bias);
}

fn small_dataset(seed: u64) -> ltls::data::Dataset {
    SyntheticSpec::multiclass(1200, 500, 48).teacher(TeacherKind::Cluster).seed(seed).generate()
}

/// Contract 2: a 1-worker Hogwild epoch on the hashed store is
/// bit-identical to the serial hashed epoch (same permutation, same step
/// counter, same float-op order through the atomic view + hash codec).
#[test]
fn hashed_one_worker_hogwild_is_bit_identical_to_serial() {
    let ds = small_dataset(301);
    let cfg = TrainConfig { averaging: false, hash_bits: 8, ..TrainConfig::default() };
    let mut serial =
        Trainer::<Trellis, HashedStore>::with_topology(cfg.clone(), ds.n_features, ds.n_labels)
            .unwrap();
    let mut hog =
        ParallelTrainer::<Trellis, HashedStore>::with_topology(cfg, ds.n_features, ds.n_labels)
            .unwrap();
    for _ in 0..2 {
        let ms = serial.epoch(&ds);
        let mh = hog.hogwild_epoch(&ds);
        assert_eq!(ms.examples, mh.examples);
        assert_eq!(ms.active_hinge, mh.active_hinge);
        assert_eq!(ms.loss_sum.to_bits(), mh.loss_sum.to_bits());
    }
    assert_eq!(serial.global_step(), hog.global_step());
    let a = serial.into_model();
    let b = hog.into_model();
    assert_eq!(a.model.w, b.model.w);
    assert_eq!(a.model.bias, b.model.bias);
}

/// Contract 3: hashed model files round-trip with the backend tag, and
/// checkpoints resume only under the matching store type and hash-bits.
#[test]
fn hashed_files_and_checkpoints_carry_backend_tag() {
    let ds = small_dataset(302);
    let cfg = TrainConfig { averaging: false, hash_bits: 7, ..TrainConfig::default() };
    let dir = std::env::temp_dir().join(format!("ltls_hashed_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Uninterrupted 3 epochs vs interrupted 2 + resume 1: identical.
    let mut full =
        ParallelTrainer::<Trellis, HashedStore>::with_topology(
            cfg.clone(),
            ds.n_features,
            ds.n_labels,
        )
        .unwrap();
    let mf = full.fit(&ds, 3);
    let mut first =
        ParallelTrainer::<Trellis, HashedStore>::with_topology(
            cfg.clone(),
            ds.n_features,
            ds.n_labels,
        )
        .unwrap();
    first.fit_with_checkpoints(&ds, 2, &dir).unwrap();
    drop(first);
    let (_, path) = io::latest_checkpoint(&dir).unwrap().expect("checkpoint written");
    let raw = std::fs::read(&path).unwrap();
    assert_eq!(io::peek_checkpoint_backend(&raw).unwrap(), ltls::model::Backend::Hashed);
    // A dense-typed load refuses the hashed checkpoint.
    let err = io::load_checkpoint::<Trellis, DenseStore>(&path).unwrap_err();
    assert!(err.contains("hashed"), "{err}");
    let ck = io::load_checkpoint::<Trellis, HashedStore>(&path).unwrap();
    assert_eq!(ck.model.model.hash_bits(), 7);
    // Resume with mismatched --hash-bits refuses…
    let wrong = TrainConfig { hash_bits: 8, ..cfg.clone() };
    let err = ParallelTrainer::<Trellis, HashedStore>::resume(wrong, ck.clone()).unwrap_err();
    assert!(err.contains("hash-bits"), "{err}");
    // …and the matching config reproduces the uninterrupted run exactly.
    let mut resumed = ParallelTrainer::<Trellis, HashedStore>::resume(cfg, ck).unwrap();
    let m3 = resumed.epoch(&ds);
    assert_eq!(m3.loss_sum.to_bits(), mf[2].loss_sum.to_bits());
    let a = full.into_model();
    let b = resumed.into_model();
    assert_eq!(a.model.w, b.model.w);

    // Model file round-trip through the backend dispatcher.
    let mpath = dir.join("hashed.ltls");
    io::save(&a, &mpath).unwrap();
    match io::load_any(&mpath).unwrap() {
        io::AnyModel::BinaryHashed(m) => {
            assert_eq!(m.model.bits, 7);
            assert_eq!(m.model.w, a.model.w);
            for i in 0..30 {
                assert_eq!(m.topk(ds.row(i), 3), a.topk(ds.row(i), 3), "row {i}");
            }
        }
        _ => panic!("hashed file dispatched to the wrong variant"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Contract 4: q8 quantization serves within 0.5% precision@1 of the f32
/// model, files round-trip, and the store stays ~4x smaller.
#[test]
fn q8_serves_within_half_a_percent() {
    let ds = SyntheticSpec::multiclass(4000, 900, 64)
        .teacher(TeacherKind::Cluster)
        .seed(303)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.25, 4);
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&train, 8);
    let dense = tr.into_model();
    let q8 = dense.quantized();
    let p_dense = precision_at_1(&dense, &test);
    let p_q8 = precision_at_1(&q8, &test);
    assert!(
        (p_dense - p_q8).abs() <= 0.005,
        "q8 p@1 {p_q8} drifted more than 0.5% from f32 {p_dense}"
    );
    assert!(
        dense.bytes() as f64 / q8.bytes() as f64 > 3.5,
        "q8 {} bytes vs dense {} bytes",
        q8.bytes(),
        dense.bytes()
    );

    // File round-trip dispatches to the q8 variant and predicts the same.
    let path = std::env::temp_dir().join(format!("ltls_q8_{}.ltls", std::process::id()));
    io::save(&q8, &path).unwrap();
    match io::load_any(&path).unwrap() {
        io::AnyModel::BinaryQ8(m) => {
            assert_eq!(m.model.q, q8.model.q);
            assert_eq!(m.model.scale, q8.model.scale);
            for i in 0..30 {
                assert_eq!(m.topk(test.row(i), 3), q8.topk(test.row(i), 3), "row {i}");
            }
        }
        _ => panic!("q8 file dispatched to the wrong variant"),
    }
    std::fs::remove_file(&path).ok();
}

/// Contract 4b: the multi-worker batched server over a q8 store answers
/// exactly what inline q8 prediction answers.
#[test]
fn q8_batched_server_matches_inline() {
    use ltls::coordinator::{BatchedLtls, BatcherConfig, PredictServer, ServerConfig};
    let ds = SyntheticSpec::multiclass(600, 400, 24).seed(304).generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 3);
    let q8 = tr.into_model().quantized();
    let inline: Vec<_> = (0..40).map(|i| q8.topk(ds.row(i), 3)).collect();
    let server = PredictServer::start(
        BatchedLtls(q8),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: std::time::Duration::from_micros(300),
            },
            queue_depth: 64,
            workers: 2,
        },
    );
    let receivers: Vec<_> = (0..40)
        .map(|i| {
            let row = ds.row(i);
            server.submit(row.indices.to_vec(), row.values.to_vec(), 3)
        })
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        assert_eq!(rx.recv().unwrap().topk, inline[i], "request {i}");
    }
    server.shutdown();
}

/// Contract 5: mmap loading is zero-copy (weights borrow the mapping) and
/// predicts identically to heap loading, for dense, hashed and q8 files.
#[test]
fn mmap_loading_matches_heap_loading_for_every_backend() {
    let ds = small_dataset(305);
    let dir = std::env::temp_dir().join(format!("ltls_mmap_any_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Dense + q8 from one training run; hashed from another.
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 3);
    let dense = tr.into_model();
    io::save(&dense, &dir.join("dense.ltls")).unwrap();
    io::save(&dense.quantized(), &dir.join("q8.ltls")).unwrap();
    let hcfg = TrainConfig { hash_bits: 8, averaging: false, ..TrainConfig::default() };
    let mut htr =
        Trainer::<Trellis, HashedStore>::with_topology(hcfg, ds.n_features, ds.n_labels).unwrap();
    htr.fit(&ds, 2);
    io::save(&htr.into_model(), &dir.join("hashed.ltls")).unwrap();

    for name in ["dense.ltls", "q8.ltls", "hashed.ltls"] {
        let path = dir.join(name);
        let heap = io::load_any(&path).unwrap();
        let mapped = io::load_any_mmap(&path).unwrap();
        assert!(!heap.is_mapped(), "{name}");
        assert!(mapped.is_mapped(), "{name}");
        assert_eq!(heap.backend(), mapped.backend(), "{name}");
        assert_eq!(heap.bytes(), mapped.bytes(), "{name}");
        let want = ltls::with_any_model!(&heap, m => {
            (0..30).map(|i| m.topk(ds.row(i), 3)).collect::<Vec<_>>()
        });
        let got = ltls::with_any_model!(&mapped, m => {
            (0..30).map(|i| m.topk(ds.row(i), 3)).collect::<Vec<_>>()
        });
        assert_eq!(want, got, "{name}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The dense store still reports the paper's exact accounting after the
/// storage refactor (the log-space headline is untouched).
#[test]
fn dense_store_accounting_is_unchanged() {
    let ds = SyntheticSpec::multiclass(200, 300, 16).seed(306).generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 1);
    let m = tr.into_model();
    let e = ltls::graph::Topology::num_edges(&m.trellis);
    assert_eq!(m.model.param_count(), e * 300 + e);
    assert_eq!(m.bytes(), (e * 300 + e) * 4);
    assert_eq!(m.model.backend(), ltls::model::Backend::Dense);
    assert_eq!(m.model.n_strips(), 300);
}
