//! Scalar ↔ vectorized kernel parity — the `simd` feature's correctness
//! pin. CI runs this suite with the feature both off (portable 8-lane
//! sweeps) and on (AVX2/NEON intrinsics where available):
//!
//! 1. **Kernel level** — `kernel::axpy` / `kernel::q8_finish` are
//!    bit-identical (f32 `to_bits`) and `kernel::i8_axpy` exactly equal
//!    (i32) to the pinned scalar oracles in `kernel::scalar`, across
//!    random strips, signs, and tail lengths where E is not a multiple of
//!    any lane width.
//! 2. **Store level** — every backend's trait `edge_scores` is
//!    bit-identical to an independent naive reimplementation of its
//!    contract (bias first, features in ascending order, one f32
//!    mul-then-add per element; pure i32 accumulation for q8), and the
//!    batched entry point is bit-identical to per-row scoring.
//! 3. **Layout** — heap-built stores get the same 64-byte weight-strip
//!    alignment the mmap path guarantees.

use ltls::kernel;
use ltls::model::{
    DenseStore, HashedStore, Q8Store, ScoreScratch, StripCodec, TrainableStore, WeightStore,
};
use ltls::sparse::SparseVec;
use ltls::util::rng::Rng;

/// Strip lengths crossing every lane boundary: multiples of 8 (portable /
/// AVX2 f32), 4 (NEON f32), 16 (AVX2 i8), and ragged tails around each.
const LENS: [usize; 20] = [0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 23, 31, 32, 33, 63, 64, 65, 100];

#[test]
fn axpy_is_bit_identical_to_scalar_oracle() {
    let mut rng = Rng::new(9001);
    for &n in &LENS {
        for round in 0..8 {
            let strip: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let init: Vec<f32> = (0..n).map(|_| rng.normal() * 3.0).collect();
            for sv in [0.0f32, 1.0, -1.0, rng.normal(), 1.0e-30, -2.5e-3] {
                let mut want = init.clone();
                kernel::scalar::axpy(&mut want, &strip, sv);
                let mut got = init.clone();
                kernel::axpy(&mut got, &strip, sv);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "n={n} round={round} sv={sv}");
            }
        }
    }
}

#[test]
fn i8_axpy_is_exactly_equal_to_scalar_oracle() {
    let mut rng = Rng::new(9002);
    for &n in &LENS {
        for round in 0..8 {
            let strip: Vec<i8> = (0..n).map(|_| (rng.index(255) as i32 - 127) as i8).collect();
            let init: Vec<i32> = (0..n).map(|_| rng.index(20001) as i32 - 10000).collect();
            for qv in [-127i32, -3, 1, 42, 127] {
                let mut want = init.clone();
                kernel::scalar::i8_axpy(&mut want, &strip, qv);
                let mut got = init.clone();
                kernel::i8_axpy(&mut got, &strip, qv);
                assert_eq!(got, want, "n={n} round={round} qv={qv}");
            }
        }
    }
}

#[test]
fn q8_finish_is_bit_identical_to_scalar_oracle() {
    let mut rng = Rng::new(9003);
    for &n in &LENS {
        let acc: Vec<i32> = (0..n).map(|_| rng.index(65001) as i32 - 32500).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let scale: Vec<f32> = (0..n).map(|_| rng.f32() * 0.01).collect();
        for sx in [0.0f32, 0.007, 1.5] {
            let mut want = vec![0.0f32; n];
            kernel::scalar::q8_finish(&mut want, &acc, &bias, &scale, sx);
            let mut got = vec![0.0f32; n];
            kernel::q8_finish(&mut got, &acc, &bias, &scale, sx);
            let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(gb, wb, "n={n} sx={sx}");
        }
    }
}

/// A random sparse row over `d` features: ascending distinct indices,
/// mixed-sign values, occasionally empty or all-zero.
fn random_row(rng: &mut Rng, d: usize, max_nnz: usize) -> (Vec<u32>, Vec<f32>) {
    let nnz = rng.index(max_nnz + 1);
    let mut idx: Vec<u32> = (0..nnz).map(|_| rng.index(d) as u32).collect();
    idx.sort_unstable();
    idx.dedup();
    let val: Vec<f32> = idx
        .iter()
        .map(|_| if rng.coin(0.1) { 0.0 } else { rng.normal() })
        .collect();
    (idx, val)
}

/// Naive dense contract: `h = bias; for each active feature (ascending),
/// h[j] += v · w[i·E + j]` — one mul then one add per element, never FMA.
fn naive_dense(m: &DenseStore, x: SparseVec) -> Vec<f32> {
    let e = m.n_edges;
    let mut out = m.bias.clone();
    for (&i, &v) in x.indices.iter().zip(x.values) {
        for (j, o) in out.iter_mut().enumerate() {
            *o += v * m.w[i as usize * e + j];
        }
    }
    out
}

/// Naive hashed contract: like dense, but through the (bucket, sign) hash
/// with the signed value `v·ξ(i)` folded in before the per-element mul.
fn naive_hashed(m: &HashedStore, x: SparseVec) -> Vec<f32> {
    let e = m.n_edges;
    let codec = m.codec();
    let mut out = m.bias.clone();
    for (&i, &v) in x.indices.iter().zip(x.values) {
        let (b, s) = codec.strip_of(i);
        let sv = v * s;
        for (j, o) in out.iter_mut().enumerate() {
            *o += sv * m.w[b as usize * e + j];
        }
    }
    out
}

/// Naive q8 contract: symmetric ±127 input quantization, skip-zero
/// levels, pure i32 accumulation, one `b + (s·sx)·acc` finish per edge.
fn naive_q8(m: &Q8Store, x: SparseVec) -> Vec<f32> {
    let e = m.n_edges;
    let mut maxv = 0.0f32;
    for &v in x.values {
        maxv = maxv.max(v.abs());
    }
    let (inv, sx) = if maxv > 0.0 { (127.0 / maxv, maxv / 127.0) } else { (0.0, 0.0) };
    let mut acc = vec![0i32; e];
    if inv > 0.0 {
        for (&i, &v) in x.indices.iter().zip(x.values) {
            let qv = (v * inv).round() as i32;
            if qv == 0 {
                continue;
            }
            for (j, a) in acc.iter_mut().enumerate() {
                *a = a.wrapping_add(qv * m.q[i as usize * e + j] as i32);
            }
        }
    }
    let mut out = vec![0.0f32; e];
    for (j, o) in out.iter_mut().enumerate() {
        *o = m.bias[j] + (m.scale[j] * sx) * acc[j] as f32;
    }
    out
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (j, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "{ctx}: edge {j} ({g} vs {w})");
    }
}

/// Every backend's kernel-routed `edge_scores` is bit-identical to the
/// naive contract, and batching is bit-identical to per-row scoring —
/// fuzzed across edge counts that straddle every lane boundary.
#[test]
fn store_scores_match_naive_contract_bitwise() {
    let mut rng = Rng::new(9004);
    for &e in &[1usize, 4, 7, 8, 17, 29, 64, 77] {
        let d = 120usize;
        let mut dense = DenseStore::new(e, d);
        for w in dense.w.as_mut_slice() {
            *w = rng.normal() * 0.3;
        }
        for b in &mut dense.bias {
            *b = rng.normal() * 0.05;
        }
        let q8 = Q8Store::quantize(&dense);
        let mut hashed = HashedStore::new(e, d, 5, 17).unwrap();
        for w in hashed.w.as_mut_slice() {
            *w = rng.normal() * 0.3;
        }
        for b in &mut hashed.bias {
            *b = rng.normal() * 0.05;
        }

        let rows: Vec<(Vec<u32>, Vec<f32>)> =
            (0..12).map(|_| random_row(&mut rng, d, 24)).collect();
        let views: Vec<SparseVec> =
            rows.iter().map(|(i, v)| SparseVec::new(i, v)).collect();

        let mut scratch = ScoreScratch::new();
        let (mut single, mut batch) = (Vec::new(), Vec::new());

        for x in &views {
            WeightStore::edge_scores(&dense, *x, &mut scratch, &mut single);
            assert_bits_eq(&single, &naive_dense(&dense, *x), &format!("dense E={e}"));
            WeightStore::edge_scores(&hashed, *x, &mut scratch, &mut single);
            assert_bits_eq(&single, &naive_hashed(&hashed, *x), &format!("hashed E={e}"));
            WeightStore::edge_scores(&q8, *x, &mut scratch, &mut single);
            assert_bits_eq(&single, &naive_q8(&q8, *x), &format!("q8 E={e}"));
        }

        WeightStore::edge_scores_batch(&dense, &views, &mut scratch, &mut batch);
        for (r, x) in views.iter().enumerate() {
            WeightStore::edge_scores(&dense, *x, &mut scratch, &mut single);
            assert_bits_eq(&batch[r * e..(r + 1) * e], &single, &format!("dense batch E={e}"));
        }
        WeightStore::edge_scores_batch(&hashed, &views, &mut scratch, &mut batch);
        for (r, x) in views.iter().enumerate() {
            WeightStore::edge_scores(&hashed, *x, &mut scratch, &mut single);
            assert_bits_eq(&batch[r * e..(r + 1) * e], &single, &format!("hashed batch E={e}"));
        }
        WeightStore::edge_scores_batch(&q8, &views, &mut scratch, &mut batch);
        for (r, x) in views.iter().enumerate() {
            WeightStore::edge_scores(&q8, *x, &mut scratch, &mut single);
            assert_bits_eq(&batch[r * e..(r + 1) * e], &single, &format!("q8 batch E={e}"));
        }
    }
}

/// Heap-built stores share the mmap path's 64-byte weight alignment.
#[test]
fn heap_store_weights_are_64_byte_aligned() {
    let dense = DenseStore::new(13, 37);
    assert_eq!(dense.w.as_ptr() as usize % 64, 0, "dense");
    let hashed = HashedStore::new(13, 37, 5, 3).unwrap();
    assert_eq!(hashed.w.as_ptr() as usize % 64, 0, "hashed");
    let q8 = Q8Store::quantize(&dense);
    assert_eq!(q8.q.as_ptr() as usize % 64, 0, "q8");
}

/// `simd_active()` reports what the build actually dispatches: it must be
/// false when the feature is off (the portable sweep path), and on
/// feature-on builds it may only be true on an arch with intrinsics.
#[test]
fn simd_active_is_consistent_with_build() {
    let active = kernel::simd_active();
    if cfg!(not(feature = "simd")) {
        assert!(!active, "simd_active() must be false without the feature");
    }
    if active {
        assert!(
            cfg!(any(target_arch = "x86_64", target_arch = "aarch64")),
            "intrinsics dispatch on an unexpected arch"
        );
    }
}
