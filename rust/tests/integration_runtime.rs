//! Cross-layer integration: the AOT artifacts (L1 Pallas + L2 JAX, lowered
//! to HLO text) executed through the rust PJRT runtime (L3) must agree
//! with the rust-native implementations on the same inputs.
//!
//! Requires `make artifacts` (skipped with a message otherwise — CI runs
//! `make test`, which builds them first).

use ltls::graph::Trellis;
use ltls::runtime::{artifacts, ArtifactMeta, DeepLtls, Engine, Tensor};
use ltls::util::rng::Rng;

fn load() -> Option<(Engine, ArtifactMeta)> {
    let dir = artifacts::default_dir();
    match ArtifactMeta::load(&dir) {
        Ok(meta) => {
            let engine = Engine::cpu().expect("PJRT CPU client");
            Some((engine, meta))
        }
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

/// The bare Pallas edge-score matmul artifact == rust-side dense matmul.
#[test]
fn pallas_edge_scores_match_rust_matmul() {
    let Some((engine, meta)) = load() else { return };
    let exe = engine.load_hlo(&meta.hlo_path("edge_scores")).expect("compile edge_scores");
    let (b, d, e) = (meta.batch, meta.d, meta.e);
    let mut rng = Rng::new(101);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..d * e).map(|_| rng.normal() * 0.1).collect();
    let bias: Vec<f32> = (0..e).map(|_| rng.normal()).collect();

    let out = exe
        .run(&[
            Tensor::f32(x.clone(), &[b, d]),
            Tensor::f32(w.clone(), &[d, e]),
            Tensor::f32(bias.clone(), &[e]),
        ])
        .expect("execute");
    let got = out[0].as_f32().unwrap();
    assert_eq!(out[0].shape(), &[b, e]);

    // Rust-side reference.
    for i in (0..b).step_by(7) {
        for j in (0..e).step_by(5) {
            let mut want = bias[j];
            for k in 0..d {
                want += x[i * d + k] * w[k * e + j];
            }
            let g = got[i * e + j];
            assert!(
                (g - want).abs() < 1e-2 * want.abs().max(1.0),
                "({i},{j}): {g} vs {want}"
            );
        }
    }
}

/// The fused ltls_infer artifact (MLP + Pallas Viterbi) == rust Viterbi on
/// the mlp_fwd artifact's edge scores — ties L1, L2, L3 decoders together.
#[test]
fn infer_artifact_matches_rust_viterbi() {
    let Some((engine, meta)) = load() else { return };
    let deep = DeepLtls::load(&engine, meta.clone()).expect("load deep model");
    let t = Trellis::new(meta.c as u64);
    let (b, d) = (meta.batch, meta.d);
    let mut rng = Rng::new(102);
    let x: Vec<f32> = (0..b * d).map(|_| if rng.coin(0.3) { rng.normal() } else { 0.0 }).collect();

    // Dense batch through mlp_fwd → rust viterbi.
    let h = deep.edge_scores(x.clone(), b).expect("fwd");
    let rust_labels: Vec<u32> = (0..b)
        .map(|i| ltls::decode::viterbi(&t, &h[i * meta.e..(i + 1) * meta.e]).label as u32)
        .collect();

    // Same batch through the fused artifact (Pallas viterbi inside).
    let mut ds = ltls::data::Dataset {
        name: "t".into(),
        features: ltls::sparse::CsrMatrix::new(d),
        labels: vec![],
        n_features: d,
        n_labels: meta.c,
        multiclass: true,
    };
    for i in 0..b {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for k in 0..d {
            let v = x[i * d + k];
            if v != 0.0 {
                idx.push(k as u32);
                val.push(v);
            }
        }
        ds.features.push_row(&idx, &val);
        ds.labels.push(vec![0]);
    }
    let rows: Vec<usize> = (0..b).collect();
    let artifact_labels = deep.predict(&ds, &rows).expect("predict");

    assert_eq!(artifact_labels, rust_labels, "L1 Pallas viterbi != L3 rust viterbi");
}

/// Training through the AOT train step reduces the loss (the §6 deep
/// experiment at miniature scale).
#[test]
fn train_step_reduces_loss() {
    let Some((engine, meta)) = load() else { return };
    let mut deep = DeepLtls::load(&engine, meta.clone()).expect("load deep model");
    let analog = ltls::data::datasets::by_name("imageNet").unwrap();
    let (train, _) = analog.generate(0.02, 11);
    let rows: Vec<usize> = (0..meta.batch.min(train.n_examples())).collect();
    let first = deep.train_batch(&train, &rows, 0.05).expect("step");
    let mut last = first;
    for _ in 0..15 {
        last = deep.train_batch(&train, &rows, 0.05).expect("step");
    }
    assert!(
        last < first,
        "loss did not decrease on a fixed batch: {first} -> {last}"
    );
}

/// meta.json ↔ rust trellis layout contract (belt-and-braces re-check in
/// the integration suite; the loader also enforces it).
#[test]
fn meta_contract_holds() {
    let Some((_, meta)) = load() else { return };
    let t = Trellis::new(meta.c as u64);
    assert_eq!(t.num_edges(), meta.e);
}
