//! End-to-end contract of the sharded scatter-gather serving tier
//! (`graph/shardmap.rs` + `model/shard.rs` + `coordinator/scatter.rs`),
//! over real TCP on loopback:
//!
//! 1. **Exactness** — a coordinator fanning out over 2 shards × 2
//!    replicas (each serving a v4 slice written by `save_shard` and
//!    loaded back through `load_any`) answers every request bit-identical
//!    to the single-process model, and never marks a reply partial while
//!    all shards are healthy.
//! 2. **Failover** — killing one replica mid-traffic drops zero of ≥200
//!    pipelined requests and still produces exact, non-partial answers:
//!    the coordinator retries each failed batch exchange on the shard's
//!    other replica.
//! 3. **Degradation** — with *both* replicas of a shard down, replies
//!    carry `"partial":true` and the top-k of the surviving shards; the
//!    `ltls_shard_degraded_total` counter records every degraded reply.
//! 4. **Recovery** — restarting a replica on its old address returns the
//!    coordinator to exact, non-partial answers with no restart of its
//!    own.
//! 5. **Merge** — `merge_topk` equals the brute-force global top-k for
//!    k ∈ {1, 5, 64}, including ties broken by smaller label id.
//! 6. **Slicing parity** — per-shard top-k lists merge back into the full
//!    model's top-k bit-for-bit across backends (dense, hashed, q8) and
//!    widths (2 and 5), purely in-process.

use ltls::coordinator::{
    merge_topk, BatchedLtls, BatcherConfig, NetConfig, NetServer, ScatterConfig, ScatterModel,
    ServerConfig,
};
use ltls::data::synthetic::SyntheticSpec;
use ltls::data::Dataset;
use ltls::eval::Predictor;
use ltls::graph::{ShardPlan, Topology, Trellis, WideTrellis};
use ltls::model::{slice_model, DenseStore, HashedStore, WeightStore};
use ltls::train::{TrainConfig, TrainedModel, Trainer};
use ltls::util::json::Json;
use ltls::util::netclient::NetClient;
use ltls::util::rng::Rng;
use std::time::{Duration, Instant};

const IO_DEADLINE: Duration = Duration::from_secs(30);

fn deadline() -> Instant {
    Instant::now() + IO_DEADLINE
}

/// `<k> <i:v> <i:v> ...` — `{}` float printing is shortest-roundtrip, so
/// the parsed f32 is bit-identical on the far side.
fn req_line(k: usize, row: ltls::sparse::SparseVec) -> String {
    let mut s = format!("{k}");
    for (&i, &v) in row.indices.iter().zip(row.values) {
        s.push_str(&format!(" {i}:{v}"));
    }
    s
}

/// Parse one coordinator reply into `(topk, partial)`.
fn parse_reply(line: &str) -> (Vec<(u32, f32)>, bool) {
    let doc = Json::parse(line).unwrap_or_else(|e| panic!("bad json {line:?}: {e}"));
    assert!(doc.get("error").is_none(), "unexpected error reply: {line}");
    let partial = doc.get("partial") == Some(&Json::Bool(true));
    let topk = doc
        .get("topk")
        .unwrap_or_else(|| panic!("no topk in {line:?}"))
        .as_arr()
        .unwrap()
        .iter()
        .map(|pair| {
            let a = pair.as_arr().unwrap();
            (a[0].as_f64().unwrap() as u32, a[1].as_f64().unwrap() as f32)
        })
        .collect();
    (topk, partial)
}

fn net_cfg() -> NetConfig {
    NetConfig {
        server: ServerConfig {
            batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(300) },
            queue_depth: 256,
            workers: 2,
        },
        ..NetConfig::default()
    }
}

/// Load a saved v4 slice and serve it on `listen` — the exact stack a
/// production shard runs (`load_any` dispatch + `BatchedLtls` pool).
fn try_start_shard(path: &std::path::Path, listen: &str) -> Result<NetServer, String> {
    let loaded = ltls::model::io::load_any(path)?;
    assert!(loaded.shard_part().is_some(), "expected a v4 shard slice at {}", path.display());
    ltls::with_any_model!(loaded, m => NetServer::start(listen, BatchedLtls(m), net_cfg()))
}

fn start_shard(path: &std::path::Path) -> NetServer {
    try_start_shard(path, "127.0.0.1:0").expect("start shard server")
}

/// Contracts 1–4: exact while healthy, failover on one dead replica,
/// degraded-partial on a dead shard, recovery after restart.
#[test]
fn coordinator_is_exact_fails_over_and_degrades() {
    let dir = std::env::temp_dir().join(format!("ltls_shard_scatter_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let ds = SyntheticSpec::multiclass(500, 300, 20).seed(55).generate();
    let cfg = TrainConfig { seed: 42, ..TrainConfig::default() };
    let mut tr = Trainer::new(cfg, ds.n_features, ds.n_labels);
    tr.fit(&ds, 3);
    let model = tr.into_model();

    // Slice into 2 shards; keep in-process copies for expected answers.
    let plan = ShardPlan::new(&model.trellis, 2).unwrap();
    let slice1 = slice_model(&model, &plan, 1).unwrap();
    let mut paths = Vec::new();
    let mut servers: Vec<Vec<NetServer>> = Vec::new();
    for shard in 0..2u32 {
        let sliced = slice_model(&model, &plan, shard).unwrap();
        let p = dir.join(format!("m.shard{shard}.ltls"));
        ltls::model::io::save_shard(&sliced, &p).unwrap();
        // 2 replicas per shard, each loading the slice through the v4
        // file path.
        servers.push(vec![start_shard(&p), start_shard(&p)]);
        paths.push(p);
    }
    let spec: Vec<Vec<String>> = servers
        .iter()
        .map(|reps| reps.iter().map(|s| s.addr().to_string()).collect())
        .collect();
    let scatter = ScatterModel::new(
        spec,
        ScatterConfig { n_features: Some(ds.n_features), ..ScatterConfig::default() },
    )
    .unwrap();
    let stats = scatter.stats();
    let coord = NetServer::start_scatter("127.0.0.1:0", scatter, net_cfg()).expect("coordinator");
    let mut c = NetClient::connect(coord.addr(), IO_DEADLINE).expect("connect coordinator");

    // Phase 1 — healthy: every pipelined reply is bit-identical to the
    // single-process model and never partial.
    let n1 = 120usize;
    for i in 0..n1 {
        c.send_line(&req_line(3, ds.row(i % ds.n_examples())), deadline()).unwrap();
    }
    for i in 0..n1 {
        let (topk, partial) = parse_reply(&c.recv_line(deadline()).unwrap());
        assert!(!partial, "healthy reply {i} marked partial");
        assert_eq!(topk, model.topk(ds.row(i % ds.n_examples()), 3), "healthy reply {i}");
    }
    assert_eq!(stats.degraded(), 0);
    assert!(stats.shard_requests(0) > 0 && stats.shard_requests(1) > 0);

    // Phase 2 — kill one replica of shard 0 mid-traffic: zero of ≥200
    // pipelined requests dropped, all exact, none partial.
    let n2 = 200usize;
    for i in 0..n2 / 2 {
        c.send_line(&req_line(3, ds.row(i % ds.n_examples())), deadline()).unwrap();
    }
    let mut replies = Vec::with_capacity(n2);
    for _ in 0..10 {
        replies.push(c.recv_line(deadline()).unwrap());
    }
    servers[0].remove(0).shutdown();
    for i in n2 / 2..n2 {
        c.send_line(&req_line(3, ds.row(i % ds.n_examples())), deadline()).unwrap();
    }
    while replies.len() < n2 {
        replies.push(c.recv_line(deadline()).unwrap());
    }
    for (i, line) in replies.iter().enumerate() {
        let (topk, partial) = parse_reply(line);
        assert!(!partial, "reply {i} partial despite a live replica");
        assert_eq!(topk, model.topk(ds.row(i % ds.n_examples()), 3), "failover reply {i}");
    }
    assert_eq!(stats.degraded(), 0, "failover must not degrade");

    // Phase 3 — kill the remaining replica of shard 0: replies degrade to
    // `"partial":true` with exactly the surviving shard's top-k.
    let dead_addr = servers[0][0].addr();
    servers[0].remove(0).shutdown();
    let n3 = 20usize;
    for i in 0..n3 {
        c.send_line(&req_line(3, ds.row(i % ds.n_examples())), deadline()).unwrap();
    }
    for i in 0..n3 {
        let (topk, partial) = parse_reply(&c.recv_line(deadline()).unwrap());
        assert!(partial, "reply {i} not partial with shard 0 fully down");
        assert_eq!(topk, slice1.topk(ds.row(i % ds.n_examples()), 3), "degraded reply {i}");
    }
    assert!(stats.degraded() >= n3 as u64, "degraded counter = {}", stats.degraded());
    assert!(stats.retries() > 0, "failover never recorded a retry");

    // The degradation is scrape-visible on the coordinator's METRICS.
    c.send_line("METRICS", deadline()).unwrap();
    let mut scrape_text = String::new();
    loop {
        let line = c.recv_line(deadline()).unwrap();
        if line == "# end" {
            break;
        }
        scrape_text.push_str(&line);
        scrape_text.push('\n');
    }
    assert!(scrape_text.contains("ltls_shard_degraded_total"), "{scrape_text}");
    assert!(scrape_text.contains("ltls_shard_requests_total{shard=\"1\"}"), "{scrape_text}");
    assert!(scrape_text.contains("ltls_shard_rtt_seconds_bucket"), "{scrape_text}");

    // Phase 4 — restart a replica of shard 0 on its old address: the
    // coordinator recovers to exact, non-partial answers by itself.
    // (std listeners set SO_REUSEADDR on unix, so the rebind is
    // immediate; retry briefly to ride out platform lag.)
    let mut revived = None;
    for _ in 0..50 {
        match try_start_shard(&paths[0], &dead_addr.to_string()) {
            Ok(s) => {
                revived = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(100)),
        }
    }
    let revived = revived.expect("rebind the dead replica's address");
    servers[0].push(revived);
    let n4 = 40usize;
    for i in 0..n4 {
        c.send_line(&req_line(3, ds.row(i % ds.n_examples())), deadline()).unwrap();
    }
    for i in 0..n4 {
        let (topk, partial) = parse_reply(&c.recv_line(deadline()).unwrap());
        assert!(!partial, "reply {i} still partial after the replica came back");
        assert_eq!(topk, model.topk(ds.row(i % ds.n_examples()), 3), "recovered reply {i}");
    }

    drop(c);
    coord.shutdown();
    for reps in servers {
        for s in reps {
            s.shutdown();
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Contract 5: the k-way heap merge equals the brute-force global top-k —
/// same (score desc, label asc) order — for k ∈ {1, 5, 64}, on random
/// part sets with quantized scores so cross-part ties are common.
#[test]
fn merge_topk_matches_brute_force_global_topk() {
    let mut rng = Rng::new(77);
    let mut merged = Vec::new();
    for trial in 0..60 {
        let n_parts = 1 + rng.index(5);
        // Globally distinct labels, dealt randomly across parts (shards
        // own disjoint label sets).
        let labels = rng.sample_distinct(5000, 1 + rng.index(90));
        let mut parts: Vec<Vec<(u32, f32)>> = vec![Vec::new(); n_parts];
        for &l in &labels {
            // Quantized scores: collisions across parts are the norm.
            let score = (rng.index(8) as f32) * 0.5 - 2.0;
            parts[rng.index(n_parts)].push((l, score));
        }
        // Each part arrives sorted by the merge key, as a shard's
        // list-Viterbi output is.
        for p in &mut parts {
            p.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        }
        let mut brute: Vec<(u32, f32)> = parts.iter().flatten().copied().collect();
        brute.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let refs: Vec<&[(u32, f32)]> = parts.iter().map(|p| p.as_slice()).collect();
        for k in [1usize, 5, 64] {
            merge_topk(&refs, k, &mut merged);
            let want = &brute[..k.min(brute.len())];
            assert_eq!(merged, want, "trial {trial} k={k} parts={n_parts}");
        }
    }
}

/// Shared body of contract 6: slice `full` into `n_shards`, then for many
/// rows and k check that merging the per-shard top-k lists reproduces the
/// full model's top-k bit-for-bit.
fn check_slices<T: Topology, S: WeightStore>(
    full: &TrainedModel<T, S>,
    ds: &Dataset,
    n_shards: u32,
) {
    let plan = ShardPlan::new(&full.trellis, n_shards).unwrap();
    let slices: Vec<_> = (0..n_shards).map(|s| slice_model(full, &plan, s).unwrap()).collect();
    let mut merged = Vec::new();
    for i in 0..60 {
        let row = ds.row(i % ds.n_examples());
        for k in [1usize, 5] {
            let parts: Vec<Vec<(u32, f32)>> = slices.iter().map(|m| m.topk(row, k)).collect();
            let refs: Vec<&[(u32, f32)]> = parts.iter().map(|p| p.as_slice()).collect();
            merge_topk(&refs, k, &mut merged);
            assert_eq!(
                merged,
                full.topk(row, k),
                "row {i} k={k} n_shards={n_shards} backend={}",
                full.model.backend().name()
            );
        }
    }
}

/// Contract 6: slicing parity across backends and widths, in-process.
#[test]
fn shard_slices_merge_back_to_the_full_topk_across_backends_and_widths() {
    let ds = SyntheticSpec::multiclass(400, 250, 24).seed(91).generate();

    // Dense, width 2 — plus its q8 quantization.
    let mut tr = Trainer::new(TrainConfig { seed: 3, ..TrainConfig::default() }, ds.n_features, ds.n_labels);
    tr.fit(&ds, 3);
    let dense2 = tr.into_model();
    check_slices(&dense2, &ds, 2);
    check_slices(&dense2, &ds, 3);
    check_slices(&dense2.quantized(), &ds, 2);

    // Hashed, width 2.
    let cfg = TrainConfig { seed: 4, hash_bits: 9, ..TrainConfig::default() };
    let mut tr = Trainer::<Trellis, HashedStore>::with_topology(cfg, ds.n_features, ds.n_labels)
        .unwrap();
    tr.fit(&ds, 3);
    check_slices(&tr.into_model(), &ds, 2);

    // Dense, width 5 (W-LTLS wide trellis).
    let cfg = TrainConfig { seed: 5, width: 5, ..TrainConfig::default() };
    let mut tr = Trainer::<WideTrellis, DenseStore>::with_topology(cfg, ds.n_features, ds.n_labels)
        .unwrap();
    tr.fit(&ds, 3);
    check_slices(&tr.into_model(), &ds, 2);
}
