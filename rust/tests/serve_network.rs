//! End-to-end contract of the network serving frontend
//! (`rust/src/coordinator/transport.rs` + `event_loop.rs` + `reload.rs`),
//! over real TCP on loopback — the whole suite runs against **both**
//! transports (`Transport::Threads` and `Transport::EventLoop`), pinning
//! that their observable behavior is identical:
//!
//! 1. **Parity** — N concurrent TCP clients receive bit-identical answers
//!    to the in-process `BatchedLtls` path (the wire format uses
//!    shortest-roundtrip float printing, so scores survive the text hop
//!    exactly).
//! 2. **Hot reload** — a mid-traffic `RELOAD` loses zero in-flight
//!    requests: every pipelined request is answered, each by exactly the
//!    old or the new model generation; a corrupt replacement file is
//!    rejected over the wire and the live model keeps serving.
//! 3. **Backpressure** — over-admission returns
//!    `{"error":...,"backpressure":true}` immediately instead of queueing
//!    unboundedly, and admitted requests still complete.
//! 4. **Drain** — `SHUTDOWN` is acknowledged, flushes everything
//!    in-flight and stops the server cleanly.
//! 5. **Half-close** — a client that pipelines a burst and then shuts
//!    down its write side still receives every reply it is owed
//!    (regression: the old writer tore down on reader exit).
//! 6. **Write backpressure** (event loop) — a client that stops reading
//!    has its reads paused at the buffer high-water mark instead of the
//!    server buffering replies unboundedly.

use ltls::coordinator::{
    BatchedLtls, BatcherConfig, NetConfig, NetServer, ReloadableLtls, ServerConfig, Transport,
};
use ltls::data::synthetic::SyntheticSpec;
use ltls::data::Dataset;
use ltls::eval::Predictor;
use ltls::train::{TrainConfig, TrainedModel, Trainer};
use ltls::util::json::Json;
use ltls::util::netclient::NetClient;
use std::net::SocketAddr;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn trained(epochs: usize, seed: u64) -> (TrainedModel, Dataset) {
    let ds = SyntheticSpec::multiclass(500, 300, 20).seed(55).generate();
    let cfg = TrainConfig { seed, ..TrainConfig::default() };
    let mut tr = Trainer::new(cfg, ds.n_features, ds.n_labels);
    tr.fit(&ds, epochs);
    (tr.into_model(), ds)
}

/// Per-operation deadline for the test client: far beyond any healthy
/// reply, so a hang fails the test instead of wedging the suite.
const IO_DEADLINE: Duration = Duration::from_secs(30);

/// A line-oriented test client over one TCP connection: the shared
/// pipelined [`NetClient`] (also the coordinator's shard client) with
/// panicking convenience wrappers.
struct Client {
    c: NetClient,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        Client { c: NetClient::connect(addr, IO_DEADLINE).expect("connect") }
    }

    fn send(&mut self, line: &str) {
        self.c.send_line(line, Instant::now() + IO_DEADLINE).expect("send request");
    }

    fn recv(&mut self) -> String {
        self.c.recv_line(Instant::now() + IO_DEADLINE).expect("read reply")
    }
}

/// `<k> <i:v> <i:v> ...` for a dataset row ({} float printing is
/// shortest-roundtrip, so the parsed f32 is bit-identical).
fn req_line(k: usize, row: ltls::sparse::SparseVec) -> String {
    let mut s = format!("{k}");
    for (&i, &v) in row.indices.iter().zip(row.values) {
        s.push_str(&format!(" {i}:{v}"));
    }
    s
}

fn parse_topk(line: &str) -> Vec<(u32, f32)> {
    let doc = Json::parse(line).unwrap_or_else(|e| panic!("bad json {line:?}: {e}"));
    assert!(doc.get("error").is_none(), "unexpected error reply: {line}");
    doc.get("topk")
        .unwrap_or_else(|| panic!("no topk in {line:?}"))
        .as_arr()
        .unwrap()
        .iter()
        .map(|pair| {
            let a = pair.as_arr().unwrap();
            (a[0].as_f64().unwrap() as u32, a[1].as_f64().unwrap() as f32)
        })
        .collect()
}

fn small_pool() -> ServerConfig {
    ServerConfig {
        batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(300) },
        queue_depth: 256,
        workers: 2,
    }
}

/// Contract 1 + 4: concurrent TCP clients are bit-identical to the
/// in-process path; METRICS/PING answer; SHUTDOWN drains cleanly.
fn concurrent_tcp_clients_match_in_process_batched_path(transport: Transport) {
    let (model, ds) = trained(3, 42);
    let n_clients = 4usize;
    let per_client = 30usize;
    // In-process ground truth (the engine-parity-pinned path).
    let expected: Vec<Vec<(u32, f32)>> =
        (0..n_clients * per_client).map(|i| model.topk(ds.row(i % ds.n_examples()), 3)).collect();

    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig { server: small_pool(), transport, ..NetConfig::default() },
    )
    .expect("start server");
    if cfg!(unix) {
        // Elsewhere the event loop falls back to the threaded transport.
        assert_eq!(server.transport(), transport);
    }
    let addr = server.addr();

    let ds = Arc::new(ds);
    let handles: Vec<_> = (0..n_clients)
        .map(|cid| {
            let ds = Arc::clone(&ds);
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                // Pipeline every request, then read every reply: replies
                // come back in submission order per connection.
                for j in 0..per_client {
                    let i = (cid * per_client + j) % ds.n_examples();
                    c.send(&req_line(3, ds.row(i)));
                }
                (0..per_client).map(|_| parse_topk(&c.recv())).collect::<Vec<_>>()
            })
        })
        .collect();
    for (cid, h) in handles.into_iter().enumerate() {
        let got = h.join().expect("client thread");
        for (j, topk) in got.into_iter().enumerate() {
            assert_eq!(
                topk,
                expected[cid * per_client + j],
                "client {cid} request {j} diverged from the in-process path"
            );
        }
    }

    // Control commands on a fresh connection.
    let mut c = Client::connect(addr);
    c.send("PING");
    assert_eq!(c.recv(), "{\"ok\":true}");
    c.send("METRICS");
    let mut metrics_text = String::new();
    loop {
        let line = c.recv();
        if line == "# end" {
            break;
        }
        metrics_text.push_str(&line);
        metrics_text.push('\n');
    }
    assert!(metrics_text.contains("ltls_requests_total"), "{metrics_text}");
    assert!(metrics_text.contains("ltls_net_live_connections"), "{metrics_text}");
    assert!(metrics_text.contains("ltls_net_open_connections"), "{metrics_text}");
    // This server has no reloadable model: RELOAD must refuse, not panic.
    c.send("RELOAD");
    let reply = c.recv();
    assert!(reply.contains("error"), "{reply}");
    // Malformed requests error without killing the connection.
    c.send("nonsense line");
    assert!(c.recv().contains("error"));
    c.send("1 999999:1.0"); // out of the model's feature range
    let reply = c.recv();
    assert!(reply.contains("out of range"), "{reply}");
    c.send("1 0:1.0");
    parse_topk(&c.recv()); // still serving

    // Drain via the control command.
    c.send("SHUTDOWN");
    assert_eq!(c.recv(), "{\"ok\":true,\"draining\":true}");
    for _ in 0..100 {
        if server.shutdown_requested() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(server.shutdown_requested());
    let (reqs, _, _) = server.metrics().counts();
    assert!(reqs as usize >= n_clients * per_client);
    server.shutdown(); // joins everything; deadlock here fails the test
}

#[test]
fn concurrent_clients_match_in_process_threads() {
    concurrent_tcp_clients_match_in_process_batched_path(Transport::Threads);
}

#[test]
fn concurrent_clients_match_in_process_event_loop() {
    concurrent_tcp_clients_match_in_process_batched_path(Transport::EventLoop);
}

/// Contract 2: a mid-traffic hot reload loses zero in-flight requests,
/// every answer comes from exactly one model generation, and a corrupt
/// replacement is rejected over the wire with the old model kept live.
fn hot_reload_mid_traffic_loses_no_requests(transport: Transport) {
    let dir = std::env::temp_dir()
        .join(format!("ltls_net_reload_{}_{transport}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (m1, ds) = trained(1, 42);
    let (m2, _) = trained(5, 43);
    let p1 = dir.join("gen1.ltls");
    let p2 = dir.join("gen2.ltls");
    ltls::model::io::save(&m1, &p1).unwrap();
    ltls::model::io::save(&m2, &p2).unwrap();

    let n_req = 200usize;
    let expect1: Vec<Vec<(u32, f32)>> =
        (0..n_req).map(|i| m1.topk(ds.row(i % ds.n_examples()), 3)).collect();
    let expect2: Vec<Vec<(u32, f32)>> =
        (0..n_req).map(|i| m2.topk(ds.row(i % ds.n_examples()), 3)).collect();

    let reloadable = Arc::new(ReloadableLtls::from_path(&p1, false).unwrap());
    let server = NetServer::start_reloadable(
        "127.0.0.1:0",
        Arc::clone(&reloadable),
        NetConfig { server: small_pool(), transport, ..NetConfig::default() },
    )
    .expect("start server");
    let addr = server.addr();

    // Traffic client: pipeline all requests, then read all replies.
    let ds2 = ds.clone();
    let traffic = std::thread::spawn(move || {
        let mut c = Client::connect(addr);
        for i in 0..n_req {
            c.send(&req_line(3, ds2.row(i % ds2.n_examples())));
        }
        (0..n_req).map(|_| parse_topk(&c.recv())).collect::<Vec<_>>()
    });

    // Mid-traffic: swap generation 1 → 2 on a control connection.
    std::thread::sleep(Duration::from_millis(5));
    let mut ctl = Client::connect(addr);
    ctl.send(&format!("RELOAD {}", p2.display()));
    let reply = ctl.recv();
    let doc = Json::parse(&reply).unwrap();
    assert_eq!(doc.get("ok"), Some(&Json::Bool(true)), "{reply}");
    assert_eq!(doc.get("epoch").and_then(|e| e.as_f64()), Some(1.0), "{reply}");

    // Zero dropped: every request got an answer, and every answer is
    // exactly one generation's output (old before the swap, new after —
    // never a mixture within one answer, never an error).
    let got = traffic.join().expect("traffic client");
    assert_eq!(got.len(), n_req);
    let mut new_gen = 0usize;
    for (i, topk) in got.iter().enumerate() {
        let is1 = *topk == expect1[i];
        let is2 = *topk == expect2[i];
        assert!(is1 || is2, "request {i} matches neither generation: {topk:?}");
        if is2 {
            new_gen += 1;
        }
    }
    println!("{}/{} answers from the new generation", new_gen, n_req);

    // Post-swap requests come from generation 2 exactly.
    assert_eq!(reloadable.epoch(), 1);
    ctl.send(&req_line(3, ds.row(7)));
    assert_eq!(parse_topk(&ctl.recv()), m2.topk(ds.row(7), 3));

    // A half-written (truncated) file is rejected over the wire; the
    // live model keeps serving.
    let bytes = ltls::model::io::serialize(&m1);
    let p3 = dir.join("halfwritten.ltls");
    std::fs::write(&p3, &bytes[..bytes.len() / 3]).unwrap();
    ctl.send(&format!("RELOAD {}", p3.display()));
    let reply = ctl.recv();
    assert!(reply.contains("reload failed"), "{reply}");
    assert!(reply.contains("current model kept"), "{reply}");
    assert_eq!(reloadable.epoch(), 1, "corrupt file must not bump the generation");
    ctl.send(&req_line(3, ds.row(7)));
    assert_eq!(parse_topk(&ctl.recv()), m2.topk(ds.row(7), 3));

    server.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn hot_reload_loses_no_requests_threads() {
    hot_reload_mid_traffic_loses_no_requests(Transport::Threads);
}

#[test]
fn hot_reload_loses_no_requests_event_loop() {
    hot_reload_mid_traffic_loses_no_requests(Transport::EventLoop);
}

/// Contract 3: over-admission answers with a backpressure error instead
/// of queueing unboundedly; admitted requests still complete.
fn over_admission_returns_backpressure_error(transport: Transport) {
    let (model, ds) = trained(1, 42);
    // One slow-batching worker: the first batch collects for 300ms (from
    // the first request's enqueue), so rapid pipelined requests pile into
    // the in-flight window and overflow the tiny admission bound.
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig {
            server: ServerConfig {
                batcher: BatcherConfig {
                    max_batch: 1024,
                    max_wait: Duration::from_millis(300),
                },
                queue_depth: 1024,
                workers: 1,
            },
            max_inflight: 4,
            max_inflight_per_conn: 4,
            transport,
            ..NetConfig::default()
        },
    )
    .expect("start server");
    let mut c = Client::connect(server.addr());
    let n_req = 40usize;
    for i in 0..n_req {
        c.send(&req_line(1, ds.row(i % ds.n_examples())));
    }
    let mut served = 0usize;
    let mut backpressured = 0usize;
    for _ in 0..n_req {
        let line = c.recv();
        let doc = Json::parse(&line).unwrap();
        if doc.get("backpressure") == Some(&Json::Bool(true)) {
            assert!(doc.get("error").unwrap().as_str().unwrap().contains("backpressure"));
            backpressured += 1;
        } else {
            parse_topk(&line);
            served += 1;
        }
    }
    assert_eq!(served + backpressured, n_req);
    assert!(served >= 1, "nothing was admitted");
    assert!(
        backpressured >= 1,
        "40 rapid requests against max_inflight=4 never saw backpressure"
    );
    assert!(server.rejected() as usize >= backpressured);
    server.shutdown();
}

#[test]
fn over_admission_backpressure_threads() {
    over_admission_returns_backpressure_error(Transport::Threads);
}

#[test]
fn over_admission_backpressure_event_loop() {
    over_admission_returns_backpressure_error(Transport::EventLoop);
}

/// One greedy pipelining client is contained by its per-connection
/// admission share: it gets backpressured while a second connection is
/// still admitted and served from the remaining global budget.
fn per_connection_cap_contains_one_greedy_client(transport: Transport) {
    let (model, ds) = trained(1, 42);
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig {
            server: ServerConfig {
                batcher: BatcherConfig {
                    max_batch: 1024,
                    max_wait: Duration::from_millis(300),
                },
                queue_depth: 1024,
                workers: 1,
            },
            max_inflight: 1024,
            max_inflight_per_conn: 2,
            transport,
            ..NetConfig::default()
        },
    )
    .expect("start server");
    let mut greedy = Client::connect(server.addr());
    let n_req = 20usize;
    for i in 0..n_req {
        greedy.send(&req_line(1, ds.row(i % ds.n_examples())));
    }
    // While the greedy client's batch is still collecting (300ms window),
    // a polite client on a fresh connection must still be admitted.
    let mut polite = Client::connect(server.addr());
    polite.send(&req_line(1, ds.row(0)));
    let polite_reply = polite.recv();
    assert!(
        !polite_reply.contains("backpressure"),
        "polite client was backpressured by someone else's pipeline: {polite_reply}"
    );
    parse_topk(&polite_reply);
    let mut served = 0usize;
    let mut backpressured = 0usize;
    for _ in 0..n_req {
        let line = greedy.recv();
        if line.contains("backpressure") {
            backpressured += 1;
        } else {
            parse_topk(&line);
            served += 1;
        }
    }
    assert_eq!(served + backpressured, n_req);
    assert!(served >= 1 && served <= 4, "per-conn cap 2 should admit ~2, got {served}");
    assert!(backpressured >= n_req - 4, "greedy client was not contained: {backpressured}");
    server.shutdown();
}

#[test]
fn per_conn_cap_contains_greedy_client_threads() {
    per_connection_cap_contains_one_greedy_client(Transport::Threads);
}

#[test]
fn per_conn_cap_contains_greedy_client_event_loop() {
    per_connection_cap_contains_one_greedy_client(Transport::EventLoop);
}

/// Contract 5 (regression): a client that pipelines a burst and then
/// half-closes its write side must still receive every reply — the old
/// writer tore the connection down when the reader thread exited,
/// dropping whatever the pool had not finished yet.
fn half_close_after_burst_still_receives_every_reply(transport: Transport) {
    let (model, ds) = trained(1, 42);
    let n_req = 50usize;
    let expected: Vec<Vec<(u32, f32)>> =
        (0..n_req).map(|i| model.topk(ds.row(i % ds.n_examples()), 3)).collect();
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig {
            server: ServerConfig {
                // A sizeable batch window so the half-close lands while
                // most of the burst is still in flight.
                batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) },
                queue_depth: 256,
                workers: 2,
            },
            max_inflight: 256,
            max_inflight_per_conn: 256,
            transport,
            ..NetConfig::default()
        },
    )
    .expect("start server");
    let mut c = Client::connect(server.addr());
    for i in 0..n_req {
        c.send(&req_line(3, ds.row(i % ds.n_examples())));
    }
    // EOF the server's read side while the burst is still being answered.
    c.c.shutdown_write().expect("half-close");
    for (i, want) in expected.iter().enumerate() {
        let got = parse_topk(&c.recv());
        assert_eq!(&got, want, "reply {i} after half-close");
    }
    // After the owed replies: clean EOF, not more data.
    let err = c.c.recv_line(Instant::now() + IO_DEADLINE).expect_err("expected EOF");
    assert_eq!(
        err.kind(),
        std::io::ErrorKind::UnexpectedEof,
        "unexpected extra data after the burst: {err}"
    );
    server.shutdown();
}

#[test]
fn half_close_flushes_owed_replies_threads() {
    half_close_after_burst_still_receives_every_reply(Transport::Threads);
}

#[test]
fn half_close_flushes_owed_replies_event_loop() {
    half_close_after_burst_still_receives_every_reply(Transport::EventLoop);
}

/// Contract 6 (event loop): a client that pipelines hard but stops
/// reading is backpressured by read-pausing at the write-buffer
/// high-water mark — the server's buffered replies stay bounded, and
/// once the client starts draining everything still arrives in order.
#[test]
fn event_loop_bounds_reply_buffer_for_slow_reader() {
    let (model, ds) = trained(1, 42);
    let cap = 4096usize;
    let n_req = 300usize;
    let expected: Vec<Vec<(u32, f32)>> =
        (0..n_req).map(|i| model.topk(ds.row(i % ds.n_examples()), 3)).collect();
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig {
            server: small_pool(),
            max_inflight: 4096,
            max_inflight_per_conn: 4096,
            transport: Transport::EventLoop,
            conn_buf_bytes: cap,
            ..NetConfig::default()
        },
    )
    .expect("start server");
    let mut c = Client::connect(server.addr());
    for i in 0..n_req {
        c.send(&req_line(3, ds.row(i % ds.n_examples())));
    }
    // Let replies pile up against a non-reading client: the loop must
    // park at the high-water mark, not buffer all 300 replies.
    std::thread::sleep(Duration::from_millis(400));
    for (i, want) in expected.iter().enumerate() {
        let got = parse_topk(&c.recv());
        assert_eq!(&got, want, "reply {i} under write backpressure");
    }
    // Peak buffered bytes ≤ high-water mark + one reply line (a frame is
    // appended whole once under the mark).
    let peak = server.write_buf_peak();
    assert!(peak >= 1, "gauge never observed a buffered reply");
    assert!(
        peak <= cap + 1024,
        "write buffer exceeded the high-water mark: peak {peak} vs cap {cap}"
    );
    server.shutdown();
}

/// Many concurrent connections on the event loop: far beyond what the
/// threaded transport's two-threads-per-connection design is sized for,
/// held open simultaneously with interleaved requests, on 2 poll
/// threads. (The 1000-connection sweep lives in `benches/serve_network`;
/// this is the correctness smoke at CI-friendly scale.)
#[test]
fn event_loop_serves_many_concurrent_connections() {
    let (model, ds) = trained(1, 42);
    let n_conns = 120usize;
    let per_conn = 3usize;
    let expected: Vec<Vec<(u32, f32)>> =
        (0..n_conns).map(|i| model.topk(ds.row(i % ds.n_examples()), 3)).collect();
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig {
            server: small_pool(),
            max_inflight: 4096,
            max_inflight_per_conn: 64,
            transport: Transport::EventLoop,
            poll_threads: 2,
            ..NetConfig::default()
        },
    )
    .expect("start server");
    let addr = server.addr();
    // Open every connection first — all live at once — then run traffic.
    let mut clients: Vec<Client> = (0..n_conns).map(|_| Client::connect(addr)).collect();
    for round in 0..per_conn {
        for (i, c) in clients.iter_mut().enumerate() {
            c.send(&req_line(3, ds.row(i % ds.n_examples())));
            if round == 0 && i == 0 {
                // Interleave a control command mid-traffic.
                c.send("PING");
            }
        }
        for (i, c) in clients.iter_mut().enumerate() {
            let got = parse_topk(&c.recv());
            assert_eq!(&got, &expected[i], "conn {i} round {round}");
            if round == 0 && i == 0 {
                assert_eq!(c.recv(), "{\"ok\":true}");
            }
        }
    }
    assert_eq!(server.accepted_connections(), n_conns as u64);
    drop(clients);
    server.shutdown();
}

/// Read a multi-line block reply (`METRICS` / `TRACE`) up to its `# end`
/// marker; returns the lines without the marker.
fn scrape(c: &mut Client, cmd: &str) -> Vec<String> {
    c.send(cmd);
    let mut lines = Vec::new();
    loop {
        let line = c.recv();
        if line == "# end" {
            break;
        }
        lines.push(line);
    }
    lines
}

/// The metric names of a scrape's sample lines, labels stripped.
fn metric_names(lines: &[String]) -> std::collections::BTreeSet<String> {
    lines
        .iter()
        .filter(|l| !l.starts_with('#'))
        .map(|l| {
            let sample = l.split_whitespace().next().unwrap();
            sample.split('{').next().unwrap().to_string()
        })
        .collect()
}

/// Drive `n` sequential prediction requests through `c`.
fn drive(c: &mut Client, ds: &Dataset, n: usize) {
    for i in 0..n {
        c.send(&req_line(3, ds.row(i % ds.n_examples())));
        parse_topk(&c.recv());
    }
}

/// Observability contract: the `METRICS` scrape exposes the *same* set of
/// metric names whichever transport served it (the scrape-diff test), and
/// every sample line is well-formed `name value`.
#[test]
fn metrics_name_set_is_identical_across_transports() {
    let mut sets = Vec::new();
    for transport in [Transport::Threads, Transport::EventLoop] {
        let (model, ds) = trained(1, 42);
        let server = NetServer::start(
            "127.0.0.1:0",
            BatchedLtls(model),
            NetConfig { server: small_pool(), transport, ..NetConfig::default() },
        )
        .expect("start server");
        let mut c = Client::connect(server.addr());
        drive(&mut c, &ds, 8);
        let lines = scrape(&mut c, "METRICS");
        for l in &lines {
            if l.starts_with('#') {
                assert!(
                    l.starts_with("# HELP ") || l.starts_with("# TYPE "),
                    "unexpected comment line {l:?}"
                );
            } else {
                assert_eq!(l.split_whitespace().count(), 2, "bad sample line {l:?}");
            }
        }
        let names = metric_names(&lines);
        for want in [
            "ltls_requests_total",
            "ltls_batches_total",
            "ltls_request_latency_seconds_bucket",
            "ltls_request_latency_seconds_sum",
            "ltls_request_latency_seconds_count",
            "ltls_queue_latency_seconds_bucket",
            "ltls_exec_latency_seconds_bucket",
            "ltls_worker_requests",
            "ltls_net_inflight",
            "ltls_net_rejected_total",
            "ltls_net_open_connections",
            "ltls_trace_sampled_total",
            "ltls_trace_slow_total",
            "ltls_train_epochs_total",
            "ltls_train_epoch_seconds_bucket",
            // Scatter-tier families: rendered zero-valued on servers with
            // no scatter tier, so the name set is topology-independent.
            "ltls_shard_requests_total",
            "ltls_shard_degraded_total",
            "ltls_shard_retries_total",
            "ltls_shard_rtt_seconds_bucket",
        ] {
            assert!(names.contains(want), "{transport}: missing {want} in {names:?}");
        }
        sets.push((transport, names));
        server.shutdown();
    }
    let (ta, a) = &sets[0];
    let (tb, b) = &sets[1];
    assert_eq!(a, b, "scrape-diff: {ta} vs {tb} expose different metric name sets");
}

/// Full cumulative histogram exposition over the wire: every `_bucket`
/// series is monotone non-decreasing in `le`, ends at `+Inf`, and its
/// final (cumulative) value equals the family's `_count`.
#[test]
fn histogram_buckets_are_monotone_and_cumulative_over_the_wire() {
    use std::collections::BTreeMap;
    let (model, ds) = trained(1, 42);
    let n_req = 25usize;
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig { server: small_pool(), ..NetConfig::default() },
    )
    .expect("start server");
    let mut c = Client::connect(server.addr());
    drive(&mut c, &ds, n_req);
    let lines = scrape(&mut c, "METRICS");

    let mut buckets: BTreeMap<String, Vec<(String, u64)>> = BTreeMap::new();
    let mut counts: BTreeMap<String, u64> = BTreeMap::new();
    let mut requests_total = 0u64;
    for l in lines.iter().filter(|l| !l.starts_with('#')) {
        let mut it = l.split_whitespace();
        let (name_full, val) = (it.next().unwrap(), it.next().unwrap());
        let base = name_full.split('{').next().unwrap();
        if let Some(fam) = base.strip_suffix("_bucket") {
            let le = name_full
                .split("le=\"")
                .nth(1)
                .unwrap_or_else(|| panic!("bucket line without le label: {l}"))
                .trim_end_matches("\"}")
                .to_string();
            let v: u64 = val.parse().unwrap_or_else(|_| panic!("bad bucket value: {l}"));
            buckets.entry(fam.to_string()).or_default().push((le, v));
        } else if let Some(fam) = base.strip_suffix("_count") {
            counts.insert(fam.to_string(), val.parse().unwrap());
        } else if base == "ltls_requests_total" {
            requests_total = val.parse().unwrap();
        }
    }
    assert!(requests_total >= n_req as u64, "requests_total = {requests_total}");
    for fam in ["ltls_request_latency_seconds", "ltls_queue_latency_seconds"] {
        assert!(buckets.contains_key(fam), "no bucket series for {fam}");
    }
    for (fam, series) in &buckets {
        assert!(!series.is_empty());
        assert_eq!(series.last().unwrap().0, "+Inf", "{fam} must close with +Inf");
        let vals: Vec<u64> = series.iter().map(|&(_, v)| v).collect();
        assert!(
            vals.windows(2).all(|w| w[0] <= w[1]),
            "{fam} buckets are not cumulative/monotone: {vals:?}"
        );
        // Strict cumulative == _count only for the serving families: they
        // are quiescent once every reply arrived (recorded before the
        // send), while the process-global train stats may be mid-record
        // from a concurrently running test in this binary.
        if !fam.starts_with("ltls_train_") {
            assert_eq!(counts.get(fam), Some(vals.last().unwrap()), "{fam}: +Inf != _count");
        }
    }
    assert_eq!(
        counts.get("ltls_request_latency_seconds"),
        Some(&requests_total),
        "request-latency count must equal requests_total"
    );
    server.shutdown();
}

/// The `TRACE` endpoint contract, on both transports: with
/// `--trace-sample 1` every request's span lands in the sampled ring;
/// the dump parses as JSON lines whose stage timelines are causal
/// (non-decreasing offsets), anchored at `accept`, and cover the full
/// pipeline (well over the 7-stage floor); a second dump is empty.
fn trace_dumps_causal_stage_timelines(transport: Transport) {
    let (model, ds) = trained(1, 42);
    let n_req = 20usize;
    let server = NetServer::start(
        "127.0.0.1:0",
        BatchedLtls(model),
        NetConfig {
            server: small_pool(),
            transport,
            trace_sample: 1,
            trace_slow_ms: 0,
            ..NetConfig::default()
        },
    )
    .expect("start server");
    let mut c = Client::connect(server.addr());
    drive(&mut c, &ds, n_req);
    let lines = scrape(&mut c, "TRACE");
    assert_eq!(lines.len(), n_req, "every request is sampled at --trace-sample 1");
    let full: std::collections::BTreeSet<&str> = [
        "accept",
        "parse",
        "admit",
        "enqueue",
        "batch_form",
        "score",
        "decode",
        "serialize",
        "write",
    ]
    .into_iter()
    .collect();
    for line in &lines {
        let doc = Json::parse(line).unwrap_or_else(|e| panic!("bad trace json {line:?}: {e}"));
        assert_eq!(doc.get("kind").and_then(|k| k.as_str()), Some("sampled"));
        let total = doc.get("total_ns").and_then(|t| t.as_f64()).unwrap();
        let stages = doc.get("stages").and_then(|s| s.as_arr()).unwrap();
        let names: Vec<&str> =
            stages.iter().map(|e| e.get("stage").unwrap().as_str().unwrap()).collect();
        let offs: Vec<f64> =
            stages.iter().map(|e| e.get("ns").and_then(|n| n.as_f64()).unwrap()).collect();
        let got: std::collections::BTreeSet<&str> = names.iter().copied().collect();
        assert_eq!(got, full, "incomplete pipeline timeline in {line}");
        assert!(offs.windows(2).all(|w| w[0] <= w[1]), "not causal: {names:?} at {offs:?}");
        assert_eq!((names[0], offs[0]), ("accept", 0.0), "span must anchor at accept");
        assert!(total >= *offs.last().unwrap(), "total_ns below the last stamp: {line}");
    }
    // Trace capture is scrape-visible on METRICS too.
    let metrics = scrape(&mut c, "METRICS");
    let sampled = metrics
        .iter()
        .find_map(|l| l.strip_prefix("ltls_trace_sampled_total "))
        .expect("ltls_trace_sampled_total missing")
        .parse::<u64>()
        .unwrap();
    assert!(sampled >= n_req as u64, "sampled_total = {sampled}");
    // The dump drains the ring: an immediate second TRACE is empty.
    assert!(scrape(&mut c, "TRACE").is_empty(), "TRACE did not drain the ring");
    server.shutdown();
}

#[test]
fn trace_dumps_causal_stage_timelines_threads() {
    trace_dumps_causal_stage_timelines(Transport::Threads);
}

#[test]
fn trace_dumps_causal_stage_timelines_event_loop() {
    trace_dumps_causal_stage_timelines(Transport::EventLoop);
}
