//! Parity suite for the zero-allocation inference engine:
//!
//! (a) batched edge scoring ≡ per-example edge scoring on random CSR
//!     blocks — bit-identical, including buffer reuse across blocks;
//! (b) `_into` decoders ≡ allocating decoders ≡ the dense
//!     `PathMatrix::topk` oracle, across k ∈ {1, 5, C};
//! (c) the multi-worker prediction server answers every request
//!     correctly and in request order under concurrent load.

use ltls::coordinator::{BatchedLtls, BatcherConfig, PredictServer, Request, Response, ServerConfig};
use ltls::coordinator::server::BatchModel;
use ltls::data::synthetic::SyntheticSpec;
use ltls::decode::{
    list_viterbi, list_viterbi_into, log_partition, log_partition_ws, posterior_marginals,
    posterior_marginals_into, viterbi, viterbi_into, Scored,
};
use ltls::engine::{DecodeWorkspace, PredictScratch};
use ltls::eval::Predictor;
use ltls::graph::pathmat::PathMatrix;
use ltls::graph::Trellis;
use ltls::model::LinearEdgeModel;
use ltls::sparse::SparseVec;
use ltls::train::{TrainConfig, Trainer};
use ltls::util::rng::Rng;
use std::sync::Arc;
use std::time::Duration;

/// (a) `edge_scores_batch` ≡ per-example `edge_scores` on random CSR
/// blocks, bit-identical, with buffers reused across blocks of different
/// shapes.
#[test]
fn batched_edge_scores_match_per_example() {
    let mut rng = Rng::new(9001);
    let mut gather = Vec::new();
    let mut batch = Vec::new();
    for (e, d) in [(28usize, 500usize), (81, 2000)] {
        let mut m = LinearEdgeModel::new(e, d);
        for w in &mut m.w {
            *w = rng.normal();
        }
        for b in &mut m.bias {
            *b = rng.normal();
        }
        for block in 0..5 {
            let n_rows = 1 + rng.index(24);
            let mut indices: Vec<Vec<u32>> = Vec::new();
            let mut values: Vec<Vec<f32>> = Vec::new();
            for _ in 0..n_rows {
                let nnz = rng.index(40); // includes empty rows
                let idx = rng.sample_distinct(d, nnz);
                let val: Vec<f32> = (0..nnz).map(|_| rng.normal()).collect();
                indices.push(idx);
                values.push(val);
            }
            let rows: Vec<SparseVec> =
                indices.iter().zip(&values).map(|(i, v)| SparseVec::new(i, v)).collect();
            m.edge_scores_batch(&rows, &mut gather, &mut batch);
            assert_eq!(batch.len(), rows.len() * e);
            for (r, row) in rows.iter().enumerate() {
                let single = m.edge_scores_vec(*row);
                assert_eq!(
                    &batch[r * e..(r + 1) * e],
                    single.as_slice(),
                    "E={e} block={block} row={r} must be bit-identical"
                );
            }
        }
    }
}

/// (b) `_into` decoders ≡ allocating decoders (bit-identical) ≡ the dense
/// oracle, across k ∈ {1, 5, C}, with one workspace reused throughout.
#[test]
fn into_decoders_match_allocating_and_oracle() {
    let mut rng = Rng::new(9002);
    let mut ws = DecodeWorkspace::new();
    let mut out: Vec<Scored> = Vec::new();
    let mut marg: Vec<f32> = Vec::new();
    for c in [2u64, 3, 22, 105, 159, 256, 1000] {
        let t = Trellis::new(c);
        let m = PathMatrix::materialize(&t);
        for trial in 0..8 {
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();

            // viterbi_into == viterbi.
            let mut s = Scored { label: 0, score: 0.0 };
            viterbi_into(&t, &h, &mut s);
            assert_eq!(s, viterbi(&t, &h), "C={c} trial={trial}");

            for k in [1usize, 5, c as usize] {
                let alloc = list_viterbi(&t, &h, k);
                list_viterbi_into(&t, &h, k, &mut ws, &mut out);
                assert_eq!(out, alloc, "C={c} k={k} trial={trial} (bit-identical)");
                let oracle = m.topk(&h, k);
                assert_eq!(out.len(), oracle.len(), "C={c} k={k}");
                for (g, w) in out.iter().zip(&oracle) {
                    assert_eq!(g.label, w.0, "C={c} k={k}");
                    assert!((g.score - w.1).abs() < 1e-4, "C={c} k={k}");
                }
            }

            // Forward–backward twins are bit-identical.
            assert_eq!(
                log_partition_ws(&t, &h, &mut ws),
                log_partition(&t, &h),
                "C={c} trial={trial}"
            );
            posterior_marginals_into(&t, &h, &mut ws, &mut marg);
            assert_eq!(marg, posterior_marginals(&t, &h), "C={c} trial={trial}");
        }
    }
}

/// (b, end-to-end) `topk_into` with a reused scratch ≡ `topk` on a
/// trained model, for every test row.
#[test]
fn trained_model_topk_into_matches_topk() {
    let ds = SyntheticSpec::multiclass(600, 400, 32).seed(9003).generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 3);
    let model = tr.into_model();
    let mut scratch = PredictScratch::new();
    let mut out = Vec::new();
    for i in 0..ds.n_examples() {
        for k in [1usize, 5] {
            model.topk_into(ds.row(i), k, &mut scratch, &mut out);
            assert_eq!(out, model.topk(ds.row(i), k), "row {i} k={k}");
        }
        assert_eq!(model.predict_with(ds.row(i), &mut scratch), model.predict(ds.row(i)));
    }
}

/// Echo model: replies with the request's first feature index, so order
/// mix-ups are visible.
struct Echo;

impl BatchModel for Echo {
    fn predict_batch(&self, batch: &[Request]) -> Vec<Response> {
        batch
            .iter()
            .map(|r| Response { topk: vec![(r.indices[0], r.values[0])], partial: false })
            .collect()
    }
    fn name(&self) -> &str {
        "echo"
    }
}

/// (c) Multi-worker server: every concurrent client receives its own
/// responses, in request order, with nothing lost or cross-wired.
#[test]
fn multi_worker_server_preserves_request_order() {
    let server = Arc::new(PredictServer::start(
        Echo,
        ServerConfig {
            batcher: BatcherConfig { max_batch: 8, max_wait: Duration::from_micros(100) },
            queue_depth: 512,
            workers: 4,
        },
    ));
    let n_clients = 4u32;
    let per_client = 500u32;
    let handles: Vec<_> = (0..n_clients)
        .map(|cid| {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let rxs: Vec<_> = (0..per_client)
                    .map(|i| server.submit(vec![cid * 10_000 + i], vec![i as f32], 1))
                    .collect();
                for (i, rx) in rxs.into_iter().enumerate() {
                    let resp = rx.recv().expect("response delivered");
                    assert_eq!(
                        resp.topk[0].0,
                        cid * 10_000 + i as u32,
                        "client {cid} response {i} out of order"
                    );
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let total = (n_clients * per_client) as u64;
    let (reqs, batches, _) = server.metrics.counts();
    assert_eq!(reqs, total);
    assert!(batches >= (total / 8).max(1), "batches={batches}");
    // Per-worker attribution covers every request exactly once.
    let pw = server.metrics.per_worker();
    assert_eq!(pw.len(), 4);
    assert_eq!(pw.iter().map(|w| w.requests).sum::<u64>(), total);
    Arc::try_unwrap(server).ok().unwrap().shutdown();
}

/// (c, batched path) The batched multi-worker server is bit-identical to
/// inline prediction under concurrent load.
#[test]
fn batched_multi_worker_server_matches_inline() {
    let ds = SyntheticSpec::multiclass(800, 600, 48).seed(9005).generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 3);
    let model = tr.into_model();
    let inline: Vec<Vec<(u32, f32)>> = (0..200).map(|i| model.topk(ds.row(i), 3)).collect();

    let server = Arc::new(PredictServer::start(
        BatchedLtls(model),
        ServerConfig {
            batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(200) },
            queue_depth: 256,
            workers: 3,
        },
    ));
    let ds = Arc::new(ds);
    let inline = Arc::new(inline);
    let handles: Vec<_> = (0..4usize)
        .map(|cid| {
            let server = Arc::clone(&server);
            let ds = Arc::clone(&ds);
            let inline = Arc::clone(&inline);
            std::thread::spawn(move || {
                let rows: Vec<usize> = (0..200).map(|i| (i + 50 * cid) % 200).collect();
                let rxs: Vec<_> = rows
                    .iter()
                    .map(|&i| {
                        let row = ds.row(i);
                        server.submit(row.indices.to_vec(), row.values.to_vec(), 3)
                    })
                    .collect();
                for (&i, rx) in rows.iter().zip(rxs) {
                    assert_eq!(rx.recv().unwrap().topk, inline[i], "row {i}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    Arc::try_unwrap(server).ok().unwrap().shutdown();
}
