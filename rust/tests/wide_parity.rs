//! W-LTLS parity suite:
//!
//! (a) `WideTrellis` at `W = 2` is **path-for-path identical** to the
//!     canonical `Trellis` — same edge layout, and (although the two run
//!     different decoder implementations: generic W-ary vs the
//!     register-specialized width-2 kernels) the same edge scores produce
//!     the same top-k labels from every decoder;
//! (b) the generic wide decoders match the dense `PathMatrix` oracle at
//!     widths > 2;
//! (c) the whole training → checkpoint → resume → serve stack works at
//!     width 4 through the same generic machinery, and a wider trellis
//!     (more parameters) does not lose accuracy against width 2.

use ltls::data::synthetic::SyntheticSpec;
use ltls::decode::{list_viterbi, log_partition, posterior_marginals, score_label, viterbi};
use ltls::engine::DecodeWorkspace;
use ltls::eval::{precision_at_1, Predictor};
use ltls::graph::pathmat::PathMatrix;
use ltls::graph::{Topology, Trellis, WideTrellis};
use ltls::model::io;
use ltls::train::{ParallelTrainer, TrainConfig, Trainer};
use ltls::util::rng::Rng;

/// (a) Same scores into both implementations → identical labels from
/// Viterbi and list-Viterbi (k ∈ {1, 5, C}), matching partition function
/// and marginals, identical per-label edge sets.
#[test]
fn width2_wide_trellis_is_path_for_path_identical() {
    let mut rng = Rng::new(5001);
    for c in [2u64, 3, 5, 22, 105, 159, 255, 256, 1000] {
        let narrow = Trellis::new(c);
        let wide = WideTrellis::new(c, 2).unwrap();
        assert_eq!(wide.num_edges(), Topology::num_edges(&narrow), "C={c}");
        for l in 0..c {
            assert_eq!(
                Topology::edges_of_label(&wide, l),
                Topology::edges_of_label(&narrow, l),
                "C={c} l={l}"
            );
        }
        for trial in 0..10 {
            let h: Vec<f32> = (0..wide.num_edges()).map(|_| rng.normal()).collect();

            let vn = viterbi(&narrow, &h);
            let vw = viterbi(&wide, &h);
            assert_eq!(vn.label, vw.label, "C={c} trial={trial}");
            assert!((vn.score - vw.score).abs() < 1e-4, "C={c} trial={trial}");

            for k in [1usize, 5, c as usize] {
                let tn = list_viterbi(&narrow, &h, k);
                let tw = list_viterbi(&wide, &h, k);
                assert_eq!(tn.len(), tw.len(), "C={c} k={k}");
                for (a, b) in tn.iter().zip(&tw) {
                    assert_eq!(a.label, b.label, "C={c} k={k} trial={trial}");
                    assert!((a.score - b.score).abs() < 1e-4, "C={c} k={k}");
                }
            }

            let zn = log_partition(&narrow, &h);
            let zw = log_partition(&wide, &h);
            assert!((zn - zw).abs() < 1e-3, "C={c}: logZ {zn} vs {zw}");

            let mn = posterior_marginals(&narrow, &h);
            let mw = posterior_marginals(&wide, &h);
            assert_eq!(mn.len(), mw.len());
            for (e, (a, b)) in mn.iter().zip(&mw).enumerate() {
                assert!((a - b).abs() < 1e-3, "C={c} edge {e}: {a} vs {b}");
            }

            for _ in 0..10 {
                let l = rng.below(c);
                let sn = score_label(&narrow, &h, l);
                let sw = score_label(&wide, &h, l);
                assert!((sn - sw).abs() < 1e-4, "C={c} l={l}");
            }
        }
    }
}

/// (b) Wide decoders match the dense oracle: viterbi == argmax, list-
/// viterbi == sorted top-k (labels and scores), logZ == brute-force
/// log-sum-exp, marginals == probability-weighted edge indicators.
#[test]
fn wide_decoders_match_dense_oracle() {
    let mut rng = Rng::new(5002);
    for (c, w) in [
        (2u64, 3u32),
        (7, 3),
        (22, 4),
        (105, 4),
        (159, 8),
        (256, 4),
        (300, 16),
        (1000, 8),
    ] {
        let t = WideTrellis::new(c, w).unwrap();
        let m = PathMatrix::materialize(&t);
        for trial in 0..12 {
            let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();

            let got = viterbi(&t, &h);
            let want = m.topk(&h, 1)[0];
            assert_eq!(got.label, want.0, "C={c} W={w} trial={trial}");
            assert!((got.score - want.1).abs() < 1e-4);

            for k in [1usize, 2, 5, 16, c as usize] {
                let got = list_viterbi(&t, &h, k);
                let want = m.topk(&h, k);
                assert_eq!(got.len(), want.len(), "C={c} W={w} k={k}");
                for (g, o) in got.iter().zip(&want) {
                    assert_eq!(g.label, o.0, "C={c} W={w} k={k} trial={trial}");
                    assert!((g.score - o.1).abs() < 1e-4, "C={c} W={w} k={k}");
                }
            }

            let scores = m.decode(&h);
            let want_z = ltls::util::logsumexp(&scores);
            let got_z = log_partition(&t, &h);
            assert!((got_z - want_z).abs() < 1e-3, "C={c} W={w}: {got_z} vs {want_z}");

            if trial % 4 == 0 {
                let logz = want_z;
                let probs: Vec<f32> = scores.iter().map(|s| (s - logz).exp()).collect();
                let mut want_m = vec![0.0f32; t.num_edges()];
                for l in 0..c {
                    for e in t.edges_of_label(l) {
                        want_m[e as usize] += probs[l as usize];
                    }
                }
                let got_m = posterior_marginals(&t, &h);
                for e in 0..t.num_edges() {
                    assert!(
                        (got_m[e] - want_m[e]).abs() < 1e-3,
                        "C={c} W={w} edge {e}: {} vs {}",
                        got_m[e],
                        want_m[e]
                    );
                }
            }
        }
    }
}

/// Generic decoders with a reused workspace are identical to fresh calls,
/// across interleaved (C, W, k) shapes.
#[test]
fn wide_reused_workspace_matches_fresh() {
    let mut rng = Rng::new(5003);
    let mut ws = DecodeWorkspace::new();
    let mut out = Vec::new();
    for _ in 0..40 {
        let c = 2 + rng.below(3000);
        let w = 2 + rng.index(15) as u32;
        let t = WideTrellis::new(c, w).unwrap();
        let k = 1 + rng.index(20);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        ltls::decode::list_viterbi_into(&t, &h, k, &mut ws, &mut out);
        assert_eq!(out, list_viterbi(&t, &h, k), "C={c} W={w} k={k}");
        assert_eq!(
            ltls::decode::log_partition_ws(&t, &h, &mut ws),
            log_partition(&t, &h),
            "C={c} W={w}"
        );
        assert_eq!(
            ltls::decode::viterbi_ws(&t, &h, &mut ws),
            viterbi(&t, &h),
            "C={c} W={w}"
        );
    }
}

/// Boosting one label's path makes it the wide-Viterbi winner.
#[test]
fn wide_boosted_label_wins() {
    let mut rng = Rng::new(5004);
    for _ in 0..100 {
        let c = 2 + rng.below(50_000);
        let w = 2 + rng.index(15) as u32;
        let t = WideTrellis::new(c, w).unwrap();
        let mut h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let target = rng.below(c);
        for e in t.edges_of_label(target) {
            h[e as usize] += 1000.0;
        }
        assert_eq!(viterbi(&t, &h).label, target, "C={c} W={w}");
    }
}

/// (c) The full stack at width 4: serial ≡ 1-worker Hogwild metrics,
/// training learns, checkpoint → resume reproduces the uninterrupted run
/// exactly, and the saved model file round-trips through `load_any`.
#[test]
fn wide_train_checkpoint_resume_roundtrip() {
    let ds = SyntheticSpec::multiclass(1200, 500, 48).seed(5005).generate();
    let cfg = TrainConfig { width: 4, averaging: false, ..TrainConfig::default() };

    // Uninterrupted 3 epochs.
    let mut full =
        ParallelTrainer::<WideTrellis>::with_topology(cfg.clone(), ds.n_features, ds.n_labels)
            .unwrap();
    let mf = full.fit(&ds, 3);
    assert!(
        mf.last().unwrap().mean_loss() < mf[0].mean_loss(),
        "wide training did not learn: {:?}",
        mf.iter().map(|m| m.mean_loss()).collect::<Vec<_>>()
    );

    // Interrupted at 2 epochs + resume for 1 == uninterrupted, exactly.
    let dir = std::env::temp_dir().join(format!("ltls_wide_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut first =
        ParallelTrainer::<WideTrellis>::with_topology(cfg.clone(), ds.n_features, ds.n_labels)
            .unwrap();
    first.fit_with_checkpoints(&ds, 2, &dir).unwrap();
    drop(first);
    let (epoch, path) = io::latest_checkpoint(&dir).unwrap().expect("checkpoint written");
    assert_eq!(epoch, 2);
    // The checkpoint records width 4: the width-2 loader must reject it.
    assert!(io::load_checkpoint::<Trellis, ltls::model::DenseStore>(&path).is_err());
    let ck = io::load_checkpoint::<WideTrellis, ltls::model::DenseStore>(&path).unwrap();
    assert_eq!(ck.model.trellis.width(), 4);
    let mut resumed = ParallelTrainer::<WideTrellis>::resume(cfg, ck).unwrap();
    let m3 = resumed.epoch(&ds);
    assert_eq!(m3.loss_sum.to_bits(), mf[2].loss_sum.to_bits());
    let a = full.into_model();
    let b = resumed.into_model();
    assert_eq!(a.model.w, b.model.w);

    // Model file round-trip through the width dispatcher.
    let mpath = dir.join("wide.ltls");
    io::save(&a, &mpath).unwrap();
    match io::load_any(&mpath).unwrap() {
        io::AnyModel::Wide(m) => {
            for i in 0..50 {
                assert_eq!(m.topk(ds.row(i), 3), a.topk(ds.row(i), 3), "row {i}");
            }
        }
        _ => panic!("width-4 dense model dispatched to the wrong variant"),
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// (c) Multi-worker Hogwild training works at width 4 and counts every
/// example; the batched multi-worker server is bit-identical to inline
/// wide prediction.
#[test]
fn wide_hogwild_and_server_smoke() {
    use ltls::coordinator::{BatchedLtls, BatcherConfig, PredictServer, ServerConfig};
    use std::sync::Arc;
    use std::time::Duration;

    let ds = SyntheticSpec::multiclass(900, 400, 32).seed(5006).generate();
    let cfg = TrainConfig { width: 4, threads: 3, averaging: false, ..TrainConfig::default() };
    let mut tr =
        ParallelTrainer::<WideTrellis>::with_topology(cfg, ds.n_features, ds.n_labels).unwrap();
    let m1 = tr.epoch(&ds);
    assert_eq!(m1.examples, 900);
    tr.fit(&ds, 2);
    let model = tr.into_model();
    let inline: Vec<Vec<(u32, f32)>> = (0..150).map(|i| model.topk(ds.row(i), 3)).collect();

    let server = Arc::new(PredictServer::start(
        BatchedLtls(model),
        ServerConfig {
            batcher: BatcherConfig { max_batch: 16, max_wait: Duration::from_micros(200) },
            queue_depth: 256,
            workers: 2,
        },
    ));
    let rxs: Vec<_> = (0..150)
        .map(|i| {
            let row = ds.row(i);
            server.submit(row.indices.to_vec(), row.values.to_vec(), 3)
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        assert_eq!(rx.recv().unwrap().topk, inline[i], "row {i}");
    }
    Arc::try_unwrap(server).ok().unwrap().shutdown();
}

/// (c) The width dial: a wider trellis has strictly more parameters and
/// does not lose accuracy against width 2 on the synthetic teacher (the
/// strict accuracy-gain claim is asserted by `benches/width_sweep.rs`,
/// which trains longer).
#[test]
fn wider_trellis_more_params_no_accuracy_loss() {
    let ds = SyntheticSpec::multiclass(3000, 800, 128)
        .teacher(ltls::data::synthetic::TeacherKind::Cluster)
        .seed(5007)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 5);
    let mut results = Vec::new();
    for width in [2u32, 8] {
        let cfg = TrainConfig { width, ..TrainConfig::default() };
        let mut tr =
            Trainer::<WideTrellis>::with_topology(cfg, ds.n_features, ds.n_labels).unwrap();
        tr.fit(&train, 6);
        let model = tr.into_model();
        results.push((width, model.model.param_count(), precision_at_1(&model, &test)));
    }
    let (_, p2, a2) = results[0];
    let (_, p8, a8) = results[1];
    assert!(p8 > p2, "W=8 params {p8} not > W=2 params {p2}");
    assert!(a8 > a2 - 0.03, "W=8 p@1 {a8} collapsed vs W=2 {a2}");
}
