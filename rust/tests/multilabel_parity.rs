//! The multilabel-objective refactor's load-bearing invariant, pinned:
//!
//! 1. A singleton label set under `Objective::Multilabel` reproduces the
//!    multiclass trainer **bit-identically** — same epoch metrics (loss
//!    bits included), same weights — on the serial engine and on the
//!    1-worker Hogwild path, across the dense and hashed backends.
//! 2. Multilabel training end-to-end actually learns (union loss, with
//!    and without PLT weighting) and the eval suite reports the top-k
//!    metric sweep on it.
//! 3. Checkpoints carry the objective: it roundtrips through
//!    save → load, and a mistyped resume (multiclass checkpoint under
//!    `--multilabel` or vice versa) errors instead of training garbage.

use ltls::data::synthetic::{SyntheticSpec, TeacherKind};
use ltls::data::Dataset;
use ltls::eval::{evaluate_with, precision_at_1, Propensities};
use ltls::graph::Trellis;
use ltls::model::{io, DenseStore, HashedStore};
use ltls::train::{EpochMetrics, Objective, ParallelTrainer, TrainConfig, Trainer};

const ML: Objective = Objective::Multilabel { plt_weight: false };
const ML_PLT: Objective = Objective::Multilabel { plt_weight: true };

fn cfg(objective: Objective) -> TrainConfig {
    TrainConfig { averaging: false, objective, ..TrainConfig::default() }
}

fn assert_metrics_identical(a: &[EpochMetrics], b: &[EpochMetrics]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.examples, y.examples, "epoch {i} examples");
        assert_eq!(x.active_hinge, y.active_hinge, "epoch {i} active_hinge");
        assert_eq!(x.new_labels, y.new_labels, "epoch {i} new_labels");
        assert_eq!(
            x.loss_sum.to_bits(),
            y.loss_sum.to_bits(),
            "epoch {i} loss_sum: {} vs {}",
            x.loss_sum,
            y.loss_sum
        );
    }
}

/// Invariant 1, serial + dense: on a multiclass dataset (every label set
/// is a singleton) the multilabel objective is the multiclass trainer,
/// bit for bit — weight averaging included (default config).
#[test]
fn singleton_serial_dense_is_bit_identical() {
    let ds = SyntheticSpec::multiclass(1500, 600, 64).seed(301).generate();
    let mut mc = Trainer::new(
        TrainConfig { objective: Objective::Multiclass, ..TrainConfig::default() },
        ds.n_features,
        ds.n_labels,
    );
    let mut ml = Trainer::new(
        TrainConfig { objective: ML, ..TrainConfig::default() },
        ds.n_features,
        ds.n_labels,
    );
    let ms = mc.fit(&ds, 3);
    let mm = ml.fit(&ds, 3);
    assert_metrics_identical(&ms, &mm);
    let a = mc.into_model();
    let b = ml.into_model();
    assert_eq!(a.model.w, b.model.w, "dense weights diverged");
    assert_eq!(a.model.bias, b.model.bias);
    // The label→path tables agree pair for pair too.
    let pa: Vec<_> = a.assigner.table.pairs().collect();
    let pb: Vec<_> = b.assigner.table.pairs().collect();
    assert_eq!(pa, pb);
}

/// Invariant 1, 1-worker Hogwild + dense: the shared `objective_step`
/// kernel behaves identically through the atomic weight view.
#[test]
fn singleton_hogwild_dense_is_bit_identical() {
    let ds = SyntheticSpec::multiclass(1200, 500, 48).seed(302).generate();
    let mut mc = ParallelTrainer::new(cfg(Objective::Multiclass), ds.n_features, ds.n_labels);
    let mut ml = ParallelTrainer::new(cfg(ML), ds.n_features, ds.n_labels);
    let mut ms = Vec::new();
    let mut mm = Vec::new();
    for _ in 0..3 {
        ms.push(mc.hogwild_epoch(&ds));
        mm.push(ml.hogwild_epoch(&ds));
    }
    assert_metrics_identical(&ms, &mm);
    assert_eq!(mc.global_step(), ml.global_step());
    let a = mc.into_model();
    let b = ml.into_model();
    assert_eq!(a.model.w, b.model.w, "hogwild weights diverged");
    assert_eq!(a.model.bias, b.model.bias);
}

/// Invariant 1, hashed backend: serial and 1-worker Hogwild, singleton
/// sets — the bucketed store sees the identical update stream.
#[test]
fn singleton_hashed_backend_is_bit_identical() {
    let ds = SyntheticSpec::multiclass(1000, 800, 48).seed(303).generate();
    let hcfg = |objective| TrainConfig { hash_bits: 9, ..cfg(objective) };

    let mut mc = Trainer::<Trellis, HashedStore>::with_topology(
        hcfg(Objective::Multiclass),
        ds.n_features,
        ds.n_labels,
    )
    .unwrap();
    let mut ml =
        Trainer::<Trellis, HashedStore>::with_topology(hcfg(ML), ds.n_features, ds.n_labels)
            .unwrap();
    assert_metrics_identical(&mc.fit(&ds, 2), &ml.fit(&ds, 2));
    assert_eq!(mc.into_model().model.w, ml.into_model().model.w, "serial hashed");

    let mut hc = ParallelTrainer::<Trellis, HashedStore>::with_topology(
        hcfg(Objective::Multiclass),
        ds.n_features,
        ds.n_labels,
    )
    .unwrap();
    let mut hl = ParallelTrainer::<Trellis, HashedStore>::with_topology(
        hcfg(ML),
        ds.n_features,
        ds.n_labels,
    )
    .unwrap();
    let mut ms = Vec::new();
    let mut mm = Vec::new();
    for _ in 0..2 {
        ms.push(hc.hogwild_epoch(&ds));
        mm.push(hl.hogwild_epoch(&ds));
    }
    assert_metrics_identical(&ms, &mm);
    assert_eq!(hc.into_model().model.w, hl.into_model().model.w, "hogwild hashed");
}

/// Invariant 2: multilabel end-to-end — the union loss learns the planted
/// multilabel teacher, PLT weighting also learns, and the eval suite
/// reports the full P@k / nDCG@k / recall@k / PSP@k sweep.
#[test]
fn multilabel_end_to_end_learns_and_reports_metrics() {
    let ds = SyntheticSpec::multilabel(3000, 1000, 48, 3)
        .teacher(TeacherKind::Cluster)
        .seed(304)
        .generate();
    assert!(!ds.multiclass);
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 7);

    for objective in [ML, ML_PLT] {
        let mut tr = Trainer::new(
            TrainConfig { objective, ..TrainConfig::default() },
            ds.n_features,
            ds.n_labels,
        );
        let ms = tr.fit(&train, 8);
        assert!(
            ms.last().unwrap().mean_loss() < ms[0].mean_loss(),
            "{objective}: loss did not decrease"
        );
        let model = tr.into_model();
        let p1 = precision_at_1(&model, &test);
        assert!(p1 > 0.3, "{objective}: precision@1 = {p1} (chance ≈ {:.3})", 3.0 / 48.0);

        let props = Propensities::from_train(&train);
        let m = evaluate_with(&model, &test, &[1, 3, 5], Some(&props));
        assert_eq!(m.precision.len(), 3);
        assert_eq!(m.ndcg.len(), 3);
        assert_eq!(m.recall.len(), 3);
        let psp = m.psp.as_ref().expect("propensity sweep present");
        assert_eq!(psp.len(), 3);
        // With 3 true labels per row, recall@5 must exceed recall@1.
        assert!(m.recall[2] > m.recall[0], "{objective}: recall not increasing in k");
        for v in m.ndcg.iter().chain(&m.recall).chain(psp) {
            assert!((0.0..=1.0 + 1e-9).contains(v), "{objective}: metric out of range: {m}");
        }
        let shown = format!("{m}");
        assert!(shown.contains("R@5=") && shown.contains("PSP@1="), "{shown}");
    }
}

/// Unlabeled rows (legal in XMLC files) are a no-op step, not a panic,
/// under both objectives.
#[test]
fn unlabeled_rows_are_skipped_safely() {
    let text = "4 6 8\n1,3 0:1 2:0.5\n, 1:1\n5 3:1\n, 4:1\n";
    let ds = ltls::data::libsvm::parse("holes", text.as_bytes()).unwrap();
    for objective in [Objective::Multiclass, ML] {
        let mut tr = Trainer::new(cfg(objective), ds.n_features, ds.n_labels);
        let ms = tr.fit(&ds, 2);
        assert_eq!(ms[0].examples, 2, "{objective}: only labeled rows count as examples");
    }
}

/// Invariant 3: the checkpoint's objective tag roundtrips, and a
/// mistyped resume errors in both directions with an actionable message.
#[test]
fn checkpoint_objective_roundtrips_and_mistyped_resume_errors() {
    let ds: Dataset = SyntheticSpec::multilabel(800, 400, 32, 2).seed(305).generate();
    let dir = std::env::temp_dir().join(format!("ltls_ml_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Multilabel run writes checkpoints…
    let mut tr = ParallelTrainer::new(cfg(ML_PLT), ds.n_features, ds.n_labels);
    tr.fit_with_checkpoints(&ds, 2, &dir).unwrap();
    let (_, path) = io::latest_checkpoint(&dir).unwrap().expect("checkpoint written");
    let ck = io::load_checkpoint::<Trellis, DenseStore>(&path).unwrap();
    assert_eq!(ck.objective, ML_PLT, "objective tag must roundtrip");

    // …which a multiclass config must refuse to resume…
    let err = ParallelTrainer::<Trellis, DenseStore>::resume(cfg(Objective::Multiclass), ck.clone())
        .unwrap_err();
    assert!(err.contains("objective"), "unhelpful error: {err}");
    assert!(err.contains("multilabel+plt"), "error names the checkpoint objective: {err}");
    // …while the matching config resumes and keeps training.
    let mut resumed = ParallelTrainer::<Trellis, DenseStore>::resume(cfg(ML_PLT), ck).unwrap();
    assert_eq!(resumed.epochs_done(), 2);
    resumed.epoch(&ds);

    // The reverse direction: multiclass checkpoint under --multilabel.
    io::clear_checkpoints(&dir).unwrap();
    let mut mc = ParallelTrainer::new(cfg(Objective::Multiclass), ds.n_features, ds.n_labels);
    mc.fit_with_checkpoints(&ds, 1, &dir).unwrap();
    let (_, path) = io::latest_checkpoint(&dir).unwrap().unwrap();
    let ck = io::load_checkpoint::<Trellis, DenseStore>(&path).unwrap();
    assert_eq!(ck.objective, Objective::Multiclass);
    let err = ParallelTrainer::<Trellis, DenseStore>::resume(cfg(ML), ck).unwrap_err();
    assert!(err.contains("objective") && err.contains("multiclass"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

/// Resume mid-run continues the multilabel trajectory exactly: epoch 3
/// after a 2-epoch checkpoint equals epoch 3 of the uninterrupted run.
#[test]
fn multilabel_checkpoint_resume_reproduces_uninterrupted_run() {
    let ds = SyntheticSpec::multilabel(900, 400, 32, 2).seed(306).generate();
    let dir = std::env::temp_dir().join(format!("ltls_ml_resume_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut full = ParallelTrainer::new(cfg(ML), ds.n_features, ds.n_labels);
    let mf = full.fit(&ds, 3);

    let mut first = ParallelTrainer::new(cfg(ML), ds.n_features, ds.n_labels);
    first.fit_with_checkpoints(&ds, 2, &dir).unwrap();
    drop(first);
    let (_, path) = io::latest_checkpoint(&dir).unwrap().unwrap();
    let ck = io::load_checkpoint::<Trellis, DenseStore>(&path).unwrap();
    assert_metrics_identical(&ck.history, &mf[..2]);
    let mut resumed = ParallelTrainer::resume(cfg(ML), ck).unwrap();
    let m3 = resumed.epoch(&ds);
    assert_metrics_identical(std::slice::from_ref(&m3), std::slice::from_ref(&mf[2]));
    let a = full.into_model();
    let b = resumed.into_model();
    assert_eq!(a.model.w, b.model.w);
    assert_eq!(a.model.bias, b.model.bias);

    std::fs::remove_dir_all(&dir).ok();
}
