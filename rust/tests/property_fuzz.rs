//! Randomized property tests over the library's core invariants
//! (proptest is not vendored offline; this uses the crate's deterministic
//! RNG with many sampled cases per property — same discipline, explicit
//! seeds, shrink-free but fully reproducible).

use ltls::data::synthetic::SyntheticSpec;
use ltls::decode::{list_viterbi, log_partition, posterior_marginals, score_label, viterbi};
use ltls::graph::codec::{edges_of_label, label_of_path, path_of_label};
use ltls::graph::{Topology, Trellis, WideTrellis};
use ltls::util::json::Json;
use ltls::util::rng::Rng;

/// Random C (2..=2^22), random scores: decoder invariants.
#[test]
fn decoder_invariants_random_c() {
    let mut rng = Rng::new(7001);
    for case in 0..300 {
        let c = 2 + rng.below((1 << 22) - 2);
        let t = Trellis::new(c);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();

        // (1) Viterbi returns a valid label whose score equals its path sum.
        let best = viterbi(&t, &h);
        assert!(best.label < c, "case {case}");
        let direct: f32 = edges_of_label(&t, best.label).iter().map(|&e| h[e as usize]).sum();
        assert!((best.score - direct).abs() < 1e-3);

        // (2) No label scores above the Viterbi winner.
        for _ in 0..20 {
            let l = rng.below(c);
            assert!(
                score_label(&t, &h, l) <= best.score + 1e-3,
                "case {case}: label {l} beats viterbi"
            );
        }

        // (3) list-Viterbi top-1 == Viterbi; descending; distinct labels.
        let k = 1 + rng.index(12);
        let top = list_viterbi(&t, &h, k);
        assert_eq!(top[0].label, best.label);
        for w in top.windows(2) {
            assert!(w[0].score >= w[1].score - 1e-5);
            assert_ne!(w[0].label, w[1].label);
        }

        // (4) logZ ≥ best score (softmax partition dominates the max).
        let lz = log_partition(&t, &h);
        assert!(lz >= best.score - 1e-3, "case {case}: logZ {lz} < max {}", best.score);

        // (5) Posterior marginals: probability-simplex cuts.
        if case % 10 == 0 {
            let m = posterior_marginals(&t, &h);
            let src = m[t.source_edge(0) as usize] + m[t.source_edge(1) as usize];
            assert!((src - 1.0).abs() < 1e-3);
            assert!(m.iter().all(|&p| (-1e-4..=1.0 + 1e-4).contains(&p)));
        }
    }
}

/// Codec bijection on randomly sampled labels at extreme C.
#[test]
fn codec_bijection_sampled_extreme_c() {
    let mut rng = Rng::new(7002);
    for _ in 0..40 {
        let c = 2 + rng.below((1u64 << 40) - 2);
        let t = Trellis::new(c);
        for _ in 0..200 {
            let l = rng.below(c);
            let p = path_of_label(&t, l);
            assert_eq!(label_of_path(&t, &p), l, "C={c}");
            // Path edges are within range and strictly increasing vertices.
            let edges = p.edges(&t);
            assert!(edges.iter().all(|&e| (e as usize) < t.num_edges()));
        }
        // Edge-count formula at extreme C.
        assert_eq!(
            t.num_edges(),
            4 * ltls::util::floor_log2(c) as usize + c.count_ones() as usize
        );
    }
}

/// Width-parameterized codec bijection: for random (C, W), every label
/// round-trips path → label → path, the per-group path counts sum to C,
/// and the DP path count over the edge list is exactly C — including the
/// power-of-two / power-of-W cases with zero early exits.
#[test]
fn wide_codec_bijection_random_c_w() {
    let mut rng = Rng::new(7010);
    fn check(c: u64, w: u32, rng: &mut Rng) {
        let t = WideTrellis::new(c, w).unwrap();
        // Terminal groups partition the label space: full + exits == C.
        let exits: u64 = t.exit_groups().iter().map(|g| g.path_count()).sum();
        assert_eq!(t.full_label_count() + exits, c, "C={c} W={w}");
        // DP path count over the edge list is exactly C.
        let mut count = vec![0u64; t.num_vertices()];
        count[0] = 1;
        for e in t.edge_list() {
            count[e.to as usize] += count[e.from as usize];
        }
        assert_eq!(count[t.num_vertices() - 1], c, "C={c} W={w}");
        // Bijection: exhaustive for small C, sampled for large C.
        if c <= 3000 {
            let mut seen = vec![false; c as usize];
            for l in 0..c {
                let p = t.path_of_label(l);
                assert_eq!(t.label_of_path(&p), l, "C={c} W={w} l={l}");
                assert!(!seen[l as usize], "C={c} W={w}: duplicate label {l}");
                seen[l as usize] = true;
            }
        } else {
            for _ in 0..300 {
                let l = rng.below(c);
                let p = t.path_of_label(l);
                assert_eq!(t.label_of_path(&p), l, "C={c} W={w} l={l}");
                let edges = t.edges_of_label(l);
                assert!(edges.iter().all(|&e| (e as usize) < t.num_edges()));
            }
        }
    }
    for _ in 0..80 {
        let c = 2 + rng.below(2000);
        let w = 2 + rng.index(31) as u32;
        check(c, w, &mut rng);
    }
    // Large-C samples.
    for _ in 0..10 {
        let c = 2 + rng.below((1u64 << 30) - 2);
        let w = 2 + rng.index(15) as u32;
        check(c, w, &mut rng);
    }
    // Exact powers: zero early exits, single aux→sink edge (the width-2
    // power-of-two case of the paper, and its W-ary generalization).
    for w in [2u32, 4, 8, 16] {
        let mut c = w as u64;
        for _ in 0..3 {
            let t = WideTrellis::new(c, w).unwrap();
            assert!(t.exit_groups().is_empty(), "C={c} W={w}");
            assert_eq!(t.n_aux_sinks(), 1, "C={c} W={w}");
            check(c, w, &mut rng);
            c *= w as u64;
        }
    }
}

/// Boosting a random label's path always makes it the Viterbi winner
/// (for margins larger than any accumulated noise).
#[test]
fn boosted_path_always_wins() {
    let mut rng = Rng::new(7003);
    for _ in 0..200 {
        let c = 2 + rng.below(100_000);
        let t = Trellis::new(c);
        let mut h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let target = rng.below(c);
        for e in edges_of_label(&t, target) {
            h[e as usize] += 1000.0;
        }
        assert_eq!(viterbi(&t, &h).label, target, "C={c}");
    }
}

/// JSON round-trip on randomized documents.
#[test]
fn json_roundtrip_fuzz() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.coin(0.5)),
            2 => Json::Num((rng.normal() * 100.0).round() as f64),
            3 => {
                let n = rng.index(8);
                Json::Str((0..n).map(|_| (b'a' + rng.index(26) as u8) as char).collect())
            }
            4 => Json::Arr((0..rng.index(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => {
                let mut m = std::collections::BTreeMap::new();
                for i in 0..rng.index(5) {
                    m.insert(format!("k{i}"), random_json(rng, depth - 1));
                }
                Json::Obj(m)
            }
        }
    }
    let mut rng = Rng::new(7004);
    for _ in 0..500 {
        let doc = random_json(&mut rng, 3);
        let text = doc.dump();
        let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(parsed, doc, "{text}");
    }
}

/// Training is deterministic given the config seed (bit-for-bit weights).
#[test]
fn training_is_deterministic() {
    let ds = SyntheticSpec::multiclass(400, 300, 20).seed(7).generate();
    let run = || {
        let mut tr = ltls::train::Trainer::new(
            ltls::train::TrainConfig::default(),
            ds.n_features,
            ds.n_labels,
        );
        tr.fit(&ds, 2);
        tr.into_model().model.w
    };
    assert_eq!(run(), run());
}

/// libsvm parser fuzz: dump(generate()) always re-parses to equal data.
#[test]
fn libsvm_fuzz_roundtrip() {
    let mut rng = Rng::new(7005);
    for case in 0..30 {
        let n = 5 + rng.index(60);
        let d = 5 + rng.index(300);
        let c = 2 + rng.index(40);
        let k = 1 + rng.index(3);
        let ds = SyntheticSpec::multilabel(n, d, c, k).seed(case as u64).generate();
        let text = ltls::data::libsvm::dump(&ds);
        let again = ltls::data::libsvm::parse("f", text.as_bytes()).unwrap();
        assert_eq!(again.n_examples(), ds.n_examples(), "case {case}");
        for i in 0..n {
            assert_eq!(again.labels_of(i), ds.labels_of(i), "case {case} row {i}");
            assert_eq!(again.row(i).indices, ds.row(i).indices, "case {case} row {i}");
        }
    }
}

/// Assignment table fuzz: interleaved binds and random_free never violate
/// the bijection.
#[test]
fn assignment_table_fuzz() {
    let mut rng = Rng::new(7006);
    for _ in 0..50 {
        let c = 4 + rng.below(5000);
        let n_labels = 1 + rng.index(c as usize);
        let mut tab = ltls::assign::AssignmentTable::new(n_labels, c);
        let mut bound = 0;
        for l in 0..n_labels as u32 {
            if rng.coin(0.7) {
                let p = tab.random_free(&mut rng).unwrap();
                tab.bind(l, p);
                bound += 1;
                assert_eq!(tab.path_of(l), Some(p));
                assert_eq!(tab.label_of(p), Some(l));
            }
        }
        assert_eq!(tab.n_assigned(), bound);
        assert_eq!(tab.n_free(), c as usize - bound);
    }
}

/// Separation loss with one positive: boosting its path by a margin far
/// above the noise always gives zero loss — no distinct path can contain
/// all of another path's edges (exit edges / differing transitions), so
/// the boosted path separates. With several positives this does NOT hold
/// (a negative can share most edges with a strongly-boosted positive
/// while the *worst* positive is a short early-exit path), which is
/// exactly why the loss uses the worst positive — checked separately.
#[test]
fn separation_loss_margin_semantics() {
    let mut rng = Rng::new(7007);
    for _ in 0..100 {
        let c = 8 + rng.below(2000);
        let t = Trellis::new(c);
        let mut h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal() * 0.1).collect();
        let pos = vec![rng.below(c)];
        for e in edges_of_label(&t, pos[0]) {
            h[e as usize] += 500.0;
        }
        let out = ltls::loss::separation_loss(&t, &h, &pos).unwrap();
        assert_eq!(out.loss, 0.0, "C={c}");
        assert_eq!(out.pos, pos[0]);
        assert_ne!(out.neg, pos[0]);

        // Multi-positive variant: the loss is still the hinge on
        // (worst positive, best negative) — verify the pair identity.
        let pos3: Vec<u64> = {
            let mut v: Vec<u64> = (0..3).map(|_| rng.below(c)).collect();
            v.sort_unstable();
            v.dedup();
            v
        };
        let out3 = ltls::loss::separation_loss(&t, &h, &pos3).unwrap();
        let worst = pos3
            .iter()
            .map(|&p| score_label(&t, &h, p))
            .fold(f32::INFINITY, f32::min);
        assert!((out3.pos_score - worst).abs() < 1e-3);
        assert!(!pos3.contains(&out3.neg));
    }
}
