//! Parallel-vs-serial training parity and checkpoint round-trips.
//!
//! The contract under test (see `rust/src/train/parallel.rs` module docs):
//!
//! 1. `ParallelTrainer` at `threads = 1, batch = 1` routes to the legacy
//!    serial `Trainer` — **bit-identical**, averaging included.
//! 2. The Hogwild worker path itself, forced at one worker
//!    (`hogwild_epoch`), is **bit-identical** to the serial path with
//!    averaging off: same epoch permutation, same step counter, same float
//!    ops through the atomic view.
//! 3. Multi-threaded Hogwild training reaches comparable loss / precision
//!    (seeded, tolerance-based — racy updates change exact trajectories).
//! 4. Checkpoint save → load → resume reproduces the uninterrupted run's
//!    final metrics and weights exactly on the deterministic path.

use ltls::data::synthetic::SyntheticSpec;
use ltls::data::Dataset;
use ltls::eval::precision_at_1;
use ltls::model::io;
use ltls::train::{EpochMetrics, ParallelTrainer, TrainConfig, Trainer};

fn dataset(n: usize, d: usize, c: usize, seed: u64) -> Dataset {
    SyntheticSpec::multiclass(n, d, c).seed(seed).generate()
}

fn cfg(threads: usize, batch: usize) -> TrainConfig {
    TrainConfig { averaging: false, threads, batch, ..TrainConfig::default() }
}

fn assert_metrics_identical(a: &[EpochMetrics], b: &[EpochMetrics]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.examples, y.examples, "epoch {i} examples");
        assert_eq!(x.active_hinge, y.active_hinge, "epoch {i} active_hinge");
        assert_eq!(x.new_labels, y.new_labels, "epoch {i} new_labels");
        assert_eq!(
            x.loss_sum.to_bits(),
            y.loss_sum.to_bits(),
            "epoch {i} loss_sum: {} vs {}",
            x.loss_sum,
            y.loss_sum
        );
    }
}

/// Contract 1: the default configuration (averaging ON) through
/// `ParallelTrainer` is the legacy serial path, bit for bit.
#[test]
fn threads1_is_the_legacy_serial_path() {
    let ds = dataset(1500, 600, 64, 101);
    let mut serial = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    let ms = serial.fit(&ds, 3);
    let mut par = ParallelTrainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    let mp = par.fit(&ds, 3);
    assert_metrics_identical(&ms, &mp);
    let a = serial.into_model();
    let b = par.into_model();
    assert_eq!(a.model.w, b.model.w);
    assert_eq!(a.model.bias, b.model.bias);
}

/// Contract 2: the Hogwild worker path at one worker is bit-identical to
/// the serial path (averaging off) — shared permutation, shared step
/// counting, identical float-op order through the atomic weight view.
#[test]
fn one_worker_hogwild_is_bit_identical_to_serial() {
    let ds = dataset(1200, 500, 48, 102);
    let mut serial = Trainer::new(cfg(1, 1), ds.n_features, ds.n_labels);
    let mut hog = ParallelTrainer::new(cfg(1, 1), ds.n_features, ds.n_labels);
    let mut ms = Vec::new();
    let mut mh = Vec::new();
    for _ in 0..3 {
        ms.push(serial.epoch(&ds));
        mh.push(hog.hogwild_epoch(&ds));
    }
    assert_metrics_identical(&ms, &mh);
    assert_eq!(serial.global_step(), hog.global_step());
    let a = serial.into_model();
    let b = hog.into_model();
    assert_eq!(a.model.w, b.model.w);
    assert_eq!(a.model.bias, b.model.bias);
    // And the label→path tables agree pair for pair.
    let pa: Vec<_> = a.assigner.table.pairs().collect();
    let pb: Vec<_> = b.assigner.table.pairs().collect();
    assert_eq!(pa, pb);
}

/// Contract 3: multi-threaded Hogwild reaches comparable quality on the
/// synthetic dataset (seeded, tolerance-based).
#[test]
fn multithreaded_reaches_comparable_loss() {
    let ds = dataset(4000, 1200, 128, 103);
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 9);

    let mut serial = ParallelTrainer::new(cfg(1, 1), ds.n_features, ds.n_labels);
    let ms = serial.fit(&train, 5);
    let mut hog = ParallelTrainer::new(cfg(4, 1), ds.n_features, ds.n_labels);
    let mh = hog.fit(&train, 5);

    // Both trajectories actually learn.
    assert!(mh.last().unwrap().mean_loss() < mh[0].mean_loss());
    // Final loss comparable: within 35% relative + small absolute slack.
    let ls = ms.last().unwrap().mean_loss();
    let lh = mh.last().unwrap().mean_loss();
    assert!(
        lh < ls * 1.35 + 0.05,
        "hogwild loss {lh} not comparable to serial {ls}"
    );
    // Predictive quality comparable on held-out data.
    let ps = precision_at_1(&serial.into_model(), &test);
    let ph = precision_at_1(&hog.into_model(), &test);
    assert!(
        ph > ps - 0.1,
        "hogwild p@1 {ph} not comparable to serial {ps}"
    );
}

/// Contract 3b: the mini-batch scoring path trains to comparable quality
/// too (same tolerance scheme), including combined with multi-threading.
#[test]
fn minibatch_reaches_comparable_loss() {
    let ds = dataset(2500, 800, 64, 104);
    let mut serial = ParallelTrainer::new(cfg(1, 1), ds.n_features, ds.n_labels);
    let ms = serial.fit(&ds, 4);
    let mut mb = ParallelTrainer::new(cfg(2, 32), ds.n_features, ds.n_labels);
    let mm = mb.fit(&ds, 4);
    let ls = ms.last().unwrap().mean_loss();
    let lm = mm.last().unwrap().mean_loss();
    assert!(mm.last().unwrap().mean_loss() < mm[0].mean_loss());
    assert!(
        lm < ls * 1.35 + 0.05,
        "minibatch loss {lm} not comparable to serial {ls}"
    );
    // Every example is still visited exactly once per epoch.
    for m in &mm {
        assert_eq!(m.examples, ds.n_examples() as u64);
    }
}

/// Contract 4: checkpoint save → load → resume reproduces the
/// uninterrupted run exactly on the deterministic (serial-route) path.
#[test]
fn checkpoint_resume_reproduces_uninterrupted_run() {
    let ds = dataset(1000, 400, 32, 105);
    let dir = std::env::temp_dir().join(format!("ltls_resume_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Uninterrupted: 3 epochs straight.
    let mut full = ParallelTrainer::new(cfg(1, 1), ds.n_features, ds.n_labels);
    let mf = full.fit(&ds, 3);

    // Interrupted: 2 epochs with checkpoints, then resume for 1 more.
    let mut first = ParallelTrainer::new(cfg(1, 1), ds.n_features, ds.n_labels);
    first.fit_with_checkpoints(&ds, 2, &dir).unwrap();
    drop(first);

    let (epoch, path) = io::latest_checkpoint(&dir).unwrap().expect("checkpoints written");
    assert_eq!(epoch, 2);
    let ck = io::load_checkpoint::<ltls::graph::Trellis, ltls::model::DenseStore>(&path).unwrap();
    assert_eq!(ck.epoch, 2);
    assert_eq!(ck.step, 2 * ds.n_examples() as u64);
    assert_eq!(ck.history.len(), 2);
    // The checkpointed history matches the uninterrupted first two epochs.
    assert_metrics_identical(&ck.history, &mf[..2]);

    // Seed mismatch is rejected loudly…
    let wrong_seed = TrainConfig { seed: 7, ..cfg(1, 1) };
    assert!(ParallelTrainer::resume(wrong_seed, ck.clone()).is_err());
    // …and the matching config resumes.
    let mut resumed = ParallelTrainer::resume(cfg(1, 1), ck).unwrap();
    assert_eq!(resumed.epochs_done(), 2);
    let m3 = resumed.epoch(&ds);

    // Epoch 3 after resume == epoch 3 of the uninterrupted run, exactly.
    assert_metrics_identical(std::slice::from_ref(&m3), std::slice::from_ref(&mf[2]));
    assert_eq!(resumed.global_step(), full.global_step());
    let a = full.into_model();
    let b = resumed.into_model();
    assert_eq!(a.model.w, b.model.w);
    assert_eq!(a.model.bias, b.model.bias);

    std::fs::remove_dir_all(&dir).ok();
}

/// Checkpointing works from the multi-threaded path too: the checkpoint
/// holds a loadable model whose quality matches the live trainer's.
#[test]
fn hogwild_checkpoint_is_a_valid_model() {
    let ds = dataset(1500, 500, 48, 106);
    let dir = std::env::temp_dir().join(format!("ltls_hogwild_ckpt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut tr = ParallelTrainer::new(cfg(4, 8), ds.n_features, ds.n_labels);
    tr.fit_with_checkpoints(&ds, 2, &dir).unwrap();
    let (_, path) = io::latest_checkpoint(&dir).unwrap().unwrap();
    let ck = io::load_checkpoint::<ltls::graph::Trellis, ltls::model::DenseStore>(&path).unwrap();
    assert_eq!(ck.step, 2 * ds.n_examples() as u64);

    let live = tr.into_model();
    let from_ck = ck.model.clone();
    // The checkpoint was taken after the same 2 epochs: identical weights.
    assert_eq!(live.model.w, from_ck.model.w);
    let p_live = precision_at_1(&live, &ds);
    let p_ck = precision_at_1(&from_ck, &ds);
    assert_eq!(p_live, p_ck);

    // Resuming from it continues training without losing quality.
    let mut resumed = ParallelTrainer::resume(cfg(4, 8), ck).unwrap();
    resumed.fit(&ds, 1);
    let p_resumed = precision_at_1(&resumed.into_model(), &ds);
    assert!(
        p_resumed > p_ck - 0.1,
        "resumed p@1 {p_resumed} collapsed vs checkpoint {p_ck}"
    );

    std::fs::remove_dir_all(&dir).ok();
}
