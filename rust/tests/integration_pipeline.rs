//! End-to-end pipeline integration over the sparse (rust-native) path:
//! synthetic data → training → prediction → serving, plus the library's
//! cross-module invariants at realistic sizes.

use ltls::assign::AssignPolicy;
use ltls::coordinator::{server::SparsePath, BatcherConfig, PredictServer, ServerConfig};
use ltls::data::datasets;
use ltls::data::synthetic::SyntheticSpec;
use ltls::eval::{precision_at_1, Predictor};
use ltls::train::{TrainConfig, Trainer};

/// Train → eval on the sector analog: the paper's "LTLS fits" regime.
#[test]
fn sector_analog_reaches_high_precision() {
    let analog = datasets::by_name("sector").unwrap();
    let (train, test) = analog.generate(0.25, 5);
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, 5);
    let model = tr.into_model();
    let p1 = precision_at_1(&model, &test);
    assert!(p1 > 0.8, "sector analog p@1 = {p1}");
    // Log-space: model is E·D + E floats.
    let e = model.trellis.num_edges();
    assert_eq!(model.model_bytes(), (e * train.n_features + e) * 4);
}

/// The imageNet analog: linear LTLS must FAIL (the paper's * row) — that
/// failure is a feature of the reproduction.
#[test]
fn imagenet_analog_linear_fails() {
    let analog = datasets::by_name("imageNet").unwrap();
    let (train, test) = analog.generate(0.1, 6);
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, 3);
    let p1 = precision_at_1(&tr.into_model(), &test);
    assert!(p1 < 0.2, "linear LTLS should fail on the dense nonlinear analog, got {p1}");
}

/// Multilabel end-to-end on the rcv1-regions analog.
#[test]
fn rcv1_analog_multilabel() {
    let analog = datasets::by_name("rcv1-regions").unwrap();
    let (train, test) = analog.generate(0.25, 7);
    assert!(!train.multiclass);
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, 6);
    let p1 = precision_at_1(&tr.into_model(), &test);
    assert!(p1 > 0.4, "rcv1 analog p@1 = {p1}");
}

/// libsvm round-trip at pipeline level: dump → load → retrain ≈ same p@1.
#[test]
fn libsvm_roundtrip_preserves_learnability() {
    let ds = SyntheticSpec::multiclass(1200, 900, 32).noise(0.02).seed(8).generate();
    let text = ltls::data::libsvm::dump(&ds);
    let again = ltls::data::libsvm::parse("rt", text.as_bytes()).unwrap();
    assert_eq!(again.n_examples(), ds.n_examples());
    let (train, test) = ltls::data::split::random_split(&again, 0.2, 1);
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, 5);
    let p1 = precision_at_1(&tr.into_model(), &test);
    assert!(p1 > 0.7, "roundtripped p@1 = {p1}");
}

/// Serving integration: the batching server returns exactly what the model
/// returns inline, under concurrent load.
#[test]
fn server_matches_inline_predictions() {
    let ds = SyntheticSpec::multiclass(800, 700, 24).seed(9).generate();
    let mut tr = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    tr.fit(&ds, 4);
    let model = tr.into_model();

    // Inline predictions first.
    let inline: Vec<Vec<(u32, f32)>> = (0..100).map(|i| model.topk(ds.row(i), 3)).collect();

    let server = PredictServer::start(
        SparsePath(model),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch: 16,
                max_wait: std::time::Duration::from_micros(200),
            },
            queue_depth: 256,
            workers: 2,
        },
    );
    let receivers: Vec<_> = (0..100)
        .map(|i| {
            let row = ds.row(i);
            server.submit(row.indices.to_vec(), row.values.to_vec(), 3)
        })
        .collect();
    for (i, rx) in receivers.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.topk, inline[i], "request {i}");
    }
    let (reqs, _, mean_batch) = server.metrics.counts();
    assert_eq!(reqs, 100);
    assert!(mean_batch >= 1.0);
    server.shutdown();
}

/// Policy-vs-random ablation at integration scale (the §5.1 claim) on a
/// moderately hard problem where assignment matters.
#[test]
fn assignment_policy_no_worse_than_random() {
    let ds = SyntheticSpec::multiclass(4000, 1500, 256)
        .pool_frac(0.35)
        .noise(0.03)
        .skew(0.8)
        .seed(10)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 2);
    let mut p1 = Vec::new();
    for policy in [AssignPolicy::TopRanked, AssignPolicy::Random] {
        let cfg = TrainConfig { policy, ..Default::default() };
        let mut tr = Trainer::new(cfg, train.n_features, train.n_labels);
        tr.fit(&train, 4);
        p1.push(precision_at_1(&tr.into_model(), &test));
    }
    assert!(
        p1[0] >= p1[1] - 0.03,
        "policy {} should not lose to random {}",
        p1[0],
        p1[1]
    );
}

/// Extreme scale smoke: C = 320338 (the LSHTCwiki analog) trains in
/// seconds and the model stays log-space.
#[test]
fn lshtcwiki_scale_trains() {
    let analog = datasets::by_name("LSHTCwiki").unwrap();
    let (train, test) = analog.generate(0.05, 11);
    assert_eq!(train.n_labels, 320_338);
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, 2);
    let model = tr.into_model();
    assert_eq!(model.trellis.num_edges(), 81); // paper Table 3
    let p1 = precision_at_1(&model, &test);
    // Tiny scale (2.5k examples over 320k classes): just beat 320338-way
    // chance by a wide margin.
    assert!(p1 > 0.01, "p@1 = {p1}");
    // Log-space: 81 edges × 20k features ≈ 6.5 MB, nowhere near C·D.
    assert!(model.model_bytes() < 10 << 20);
}

/// L1 soft-thresholding (the † rows): shrinks the model without destroying
/// accuracy on the overfitting-prone analog.
#[test]
fn l1_thresholding_sparsifies() {
    let analog = datasets::by_name("LSHTC1").unwrap();
    let (train, test) = analog.generate(0.08, 12);
    let base_cfg = TrainConfig::default();
    let mut tr = Trainer::new(base_cfg.clone(), train.n_features, train.n_labels);
    tr.fit(&train, 3);
    let dense_model = tr.into_model();
    let dense_p1 = precision_at_1(&dense_model, &test);
    let sparse_model = ltls::model::l1::soft_threshold_model(&dense_model.model, 0.02);
    assert!(sparse_model.zero_fraction() > dense_model.model.zero_fraction());
    let _ = dense_p1; // accuracy comparison is the ablation bench's job
}
