//! `bench_check` — the CI perf-regression gate.
//!
//! Reads raw bench stdout files (any line of the form `json: {...}`, as
//! emitted by `benches/decode.rs`, `benches/serve_throughput.rs` and
//! `benches/train_parallel.rs`), flattens them into `bench.metric` /
//! `bench.disc=V.metric` scalar metrics, and compares them against a
//! committed baseline file:
//!
//! ```text
//! bench_check --baseline BENCH_BASELINE.json [--write current.json] \
//!     bench-out/decode.txt bench-out/serve_throughput.txt ...
//! ```
//!
//! Baseline format (see `BENCH_BASELINE.json`):
//!
//! ```json
//! {
//!   "tolerance": 0.25,
//!   "metrics": {
//!     "train_parallel.speedup_4v1": {"baseline": 1.5},
//!     "decode.viterbi_ratio": {"baseline": 20.0, "higher_is_better": false,
//!                               "tolerance": 2.0},
//!     "serve_throughput.workers=1.req_per_s": null
//!   }
//! }
//! ```
//!
//! * An entry with a `"baseline"` number is **gated**: with
//!   `higher_is_better` (the default) the job fails when
//!   `current < baseline·(1 − tolerance)`; with `higher_is_better: false`
//!   it fails when `current > baseline·(1 + tolerance)`. A gated metric
//!   that no bench produced also fails (bench rot).
//! * A `null` entry is **record-only**: its current value is printed and
//!   written to `--write`, never failed on. Absolute throughputs are
//!   machine-dependent, so they start as record-only; ratio metrics
//!   (speedups, scaling shapes) are gated.
//!
//! `--write` dumps the flattened current metrics as one JSON object — CI
//! uploads it as an artifact; paste values from a trusted runner into the
//! baseline to tighten the gate.

use ltls::util::args::Args;
use ltls::util::json::Json;
use std::collections::BTreeMap;

/// Result-array keys that name a configuration rather than a measurement.
/// `kernel` discriminates scoring-kernel rows: 0 = pinned scalar oracle,
/// 1 = dispatched fast path (portable sweep or SIMD intrinsics).
/// `transport` discriminates network-frontend rows: 0 = thread-per-
/// connection, 1 = poll(2) event loop; `clients` is the concurrent
/// connection count of a sweep row. `trace` discriminates observability
/// rows: 0 = request tracing disabled, 1 = the default sampling plus the
/// slow-request ring. `shards` discriminates scatter-gather rows: the
/// number of label-space shards the coordinator fans out over.
/// `multilabel` discriminates training-objective rows of the multilabel
/// sweep: 0 = singleton-degenerate (label sets truncated to one gold
/// path), 1 = union-of-gold-paths loss, 2 = union loss + PLT weighting.
const DISCRIMINATORS: [&str; 13] = [
    "workers",
    "threads",
    "batch",
    "k",
    "width",
    "backend",
    "hash_bits",
    "kernel",
    "transport",
    "clients",
    "trace",
    "shards",
    "multilabel",
];

fn main() {
    let args = Args::from_env();
    std::process::exit(run(&args));
}

fn run(args: &Args) -> i32 {
    let baseline_path = args.get_str("baseline", "BENCH_BASELINE.json");
    if args.positional.is_empty() {
        eprintln!("usage: bench_check --baseline <file> [--write <file>] <bench-output>...");
        return 2;
    }
    let mut current: BTreeMap<String, f64> = BTreeMap::new();
    for path in &args.positional {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("error: {path}: {e}");
                return 2;
            }
        };
        for doc in extract_json_lines(&text) {
            flatten(&doc, &mut current);
        }
    }
    if current.is_empty() {
        eprintln!("error: no `json: {{...}}` lines found in any input file");
        return 2;
    }
    if let Some(out) = args.get("write") {
        let obj = Json::Obj(current.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect());
        if let Err(e) = std::fs::write(out, obj.dump() + "\n") {
            eprintln!("error: writing {out}: {e}");
            return 2;
        }
    }
    let baseline_text = match std::fs::read_to_string(baseline_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("error: {baseline_path}: {e}");
            return 2;
        }
    };
    match check_against_baseline(&baseline_text, &current) {
        Ok(report) => {
            print!("{}", report.text);
            if report.failures == 0 {
                println!("bench_check: all {} gated metric(s) within tolerance", report.gated);
                0
            } else {
                println!("bench_check: {} regression(s) detected", report.failures);
                1
            }
        }
        Err(e) => {
            eprintln!("error: {baseline_path}: {e}");
            2
        }
    }
}

/// Parse every `json: {...}` line of a bench's stdout.
fn extract_json_lines(text: &str) -> Vec<Json> {
    text.lines()
        .filter_map(|l| l.trim().strip_prefix("json: "))
        .filter_map(|s| Json::parse(s).ok())
        .collect()
}

/// Flatten one bench JSON object into `bench.metric` scalars. Top-level
/// numeric fields become `bench.<key>`; entries of a `results` array
/// become `bench.<disc>=<v>[.<disc>=<v>…].<key>` using the discriminator
/// keys present in the entry.
fn flatten(doc: &Json, out: &mut BTreeMap<String, f64>) {
    let Some(bench) = doc.get("bench").and_then(|b| b.as_str()) else { return };
    if let Json::Obj(map) = doc {
        for (k, v) in map {
            if k == "bench" || k == "results" {
                continue;
            }
            if let Some(nv) = v.as_f64() {
                out.insert(format!("{bench}.{k}"), nv);
            }
        }
    }
    let Some(results) = doc.get("results").and_then(|r| r.as_arr()) else { return };
    for item in results {
        let Json::Obj(imap) = item else { continue };
        let disc: Vec<String> = DISCRIMINATORS
            .iter()
            .filter_map(|d| {
                imap.get(*d).and_then(|v| v.as_f64()).map(|n| format!("{d}={}", n as i64))
            })
            .collect();
        let prefix = if disc.is_empty() {
            bench.to_string()
        } else {
            format!("{bench}.{}", disc.join("."))
        };
        for (k, v) in imap {
            if DISCRIMINATORS.contains(&k.as_str()) {
                continue;
            }
            if let Some(nv) = v.as_f64() {
                out.insert(format!("{prefix}.{k}"), nv);
            }
        }
    }
}

struct Report {
    text: String,
    gated: usize,
    failures: usize,
}

fn check_against_baseline(
    baseline_text: &str,
    current: &BTreeMap<String, f64>,
) -> Result<Report, String> {
    use std::fmt::Write as _;
    let doc = Json::parse(baseline_text)?;
    let global_tol = doc.get("tolerance").and_then(|t| t.as_f64()).unwrap_or(0.25);
    let Some(Json::Obj(metrics)) = doc.get("metrics") else {
        return Err("baseline has no \"metrics\" object".into());
    };
    let mut text = String::new();
    let mut gated = 0usize;
    let mut failures = 0usize;
    for (name, spec) in metrics {
        match spec {
            Json::Null => match current.get(name) {
                Some(v) => {
                    let _ = writeln!(text, "record     {name} = {v:.4}");
                }
                None => {
                    let _ = writeln!(text, "record     {name} (absent this run)");
                }
            },
            spec => {
                let Some(base) = spec.get("baseline").and_then(|b| b.as_f64()) else {
                    return Err(format!("metric {name:?}: entry must be null or have \"baseline\""));
                };
                gated += 1;
                let higher = match spec.get("higher_is_better") {
                    Some(Json::Bool(b)) => *b,
                    _ => true,
                };
                let tol = spec.get("tolerance").and_then(|t| t.as_f64()).unwrap_or(global_tol);
                match current.get(name) {
                    None => {
                        failures += 1;
                        let _ = writeln!(
                            text,
                            "GATE FAIL  {name}: not produced by any bench output (rot?)"
                        );
                    }
                    Some(&v) => {
                        let ok = if higher {
                            v >= base * (1.0 - tol)
                        } else {
                            v <= base * (1.0 + tol)
                        };
                        let dir = if higher { "min" } else { "max" };
                        let bound =
                            if higher { base * (1.0 - tol) } else { base * (1.0 + tol) };
                        if ok {
                            let _ = writeln!(
                                text,
                                "gate ok    {name} = {v:.4} (baseline {base:.4}, {dir} {bound:.4})"
                            );
                        } else {
                            failures += 1;
                            let _ = writeln!(
                                text,
                                "GATE FAIL  {name} = {v:.4} (baseline {base:.4}, {dir} {bound:.4})"
                            );
                        }
                    }
                }
            }
        }
    }
    // Metrics a bench produced that the baseline does not know about are
    // record-only (never failed on): printed here and included in --write,
    // so new bench rows (e.g. a new width) surface instead of vanishing.
    // Promote one to a gated entry by adding it to the baseline file.
    for (name, v) in current {
        if !metrics.contains_key(name) {
            let _ = writeln!(text, "record new {name} = {v:.4} (not in baseline; record-only)");
        }
    }
    Ok(Report { text, gated, failures })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn current_from(text: &str) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for doc in extract_json_lines(text) {
            flatten(&doc, &mut out);
        }
        out
    }

    const SAMPLE: &str = r#"
some human-readable table
json: {"bench":"serve_throughput","clients":4,"speedup_best_v1":1.8,"results":[{"workers":1,"req_per_s":1000.0},{"workers":4,"req_per_s":1800.0}]}
json: {"bench":"train_parallel","speedup_4v1":2.1,"results":[{"threads":4,"batch":16,"examples_per_s":5000.0}]}
trailing noise
"#;

    #[test]
    fn flattens_top_level_and_results() {
        let c = current_from(SAMPLE);
        assert_eq!(c["serve_throughput.speedup_best_v1"], 1.8);
        assert_eq!(c["serve_throughput.clients"], 4.0);
        assert_eq!(c["serve_throughput.workers=1.req_per_s"], 1000.0);
        assert_eq!(c["serve_throughput.workers=4.req_per_s"], 1800.0);
        // Multiple discriminators compose, so rows can't collide.
        assert_eq!(c["train_parallel.threads=4.batch=16.examples_per_s"], 5000.0);
        assert_eq!(c["train_parallel.speedup_4v1"], 2.1);
    }

    #[test]
    fn ignores_lines_that_are_not_bench_json() {
        let c = current_from("json: {\"no_bench_key\":1}\njson: not json at all\n");
        assert!(c.is_empty());
    }

    #[test]
    fn gate_passes_within_tolerance_and_fails_beyond() {
        let c = current_from(SAMPLE);
        // Passing: 2.1 ≥ 1.5·0.75.
        let base = r#"{"tolerance":0.25,"metrics":{"train_parallel.speedup_4v1":{"baseline":1.5}}}"#;
        let r = check_against_baseline(base, &c).unwrap();
        assert_eq!(r.failures, 0);
        assert_eq!(r.gated, 1);
        // Failing: 2.1 < 4.0·0.75.
        let base = r#"{"tolerance":0.25,"metrics":{"train_parallel.speedup_4v1":{"baseline":4.0}}}"#;
        let r = check_against_baseline(base, &c).unwrap();
        assert_eq!(r.failures, 1);
        assert!(r.text.contains("GATE FAIL"));
    }

    #[test]
    fn width_rows_flatten_and_new_metrics_are_record_only() {
        let c = current_from(
            "json: {\"bench\":\"width_sweep\",\"p1_gain_8v2\":0.1,\"results\":[{\"width\":2,\"p1\":0.5,\"params\":49500},{\"width\":8,\"p1\":0.7,\"params\":126000}]}\n",
        );
        assert_eq!(c["width_sweep.width=2.p1"], 0.5);
        assert_eq!(c["width_sweep.width=8.params"], 126000.0);
        assert_eq!(c["width_sweep.p1_gain_8v2"], 0.1);
        // Unknown-but-present metrics never fail the gate — they are
        // reported as record-only lines.
        let base = r#"{"metrics":{"width_sweep.width=2.p1":null}}"#;
        let r = check_against_baseline(base, &c).unwrap();
        assert_eq!(r.failures, 0);
        assert!(r.text.contains("record new width_sweep.width=8.p1"), "{}", r.text);
    }

    #[test]
    fn backend_and_hash_bits_discriminate_footprint_rows() {
        let c = current_from(
            "json: {\"bench\":\"memory_footprint\",\"q8_p1_delta\":0.002,\"results\":[{\"backend\":0,\"hash_bits\":0,\"model_bytes\":270000.0,\"p1\":0.7},{\"backend\":1,\"hash_bits\":9,\"model_bytes\":67000.0,\"p1\":0.65},{\"backend\":2,\"hash_bits\":0,\"model_bytes\":68000.0,\"p1\":0.699}]}\n",
        );
        assert_eq!(c["memory_footprint.q8_p1_delta"], 0.002);
        assert_eq!(c["memory_footprint.backend=0.hash_bits=0.model_bytes"], 270000.0);
        assert_eq!(c["memory_footprint.backend=1.hash_bits=9.p1"], 0.65);
        assert_eq!(c["memory_footprint.backend=2.hash_bits=0.model_bytes"], 68000.0);
        // The delta gate: fails only when the q8 drift exceeds the bound.
        let base = r#"{"metrics":{"memory_footprint.q8_p1_delta":{"baseline":0.005,"higher_is_better":false,"tolerance":0.0}}}"#;
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 0);
        let mut worse = c.clone();
        worse.insert("memory_footprint.q8_p1_delta".into(), 0.02);
        assert_eq!(check_against_baseline(base, &worse).unwrap().failures, 1);
    }

    #[test]
    fn kernel_rows_discriminate_scalar_vs_dispatched() {
        let c = current_from(
            "json: {\"bench\":\"decode\",\"kernel_axpy_speedup\":3.1,\"results\":[{\"kernel\":0,\"axpy_ns\":800.0},{\"kernel\":1,\"axpy_ns\":260.0}]}\n",
        );
        assert_eq!(c["decode.kernel=0.axpy_ns"], 800.0);
        assert_eq!(c["decode.kernel=1.axpy_ns"], 260.0);
        assert_eq!(c["decode.kernel_axpy_speedup"], 3.1);
    }

    #[test]
    fn transport_and_clients_discriminate_connection_sweep_rows() {
        let c = current_from(
            "json: {\"bench\":\"serve_network\",\"many_conn_ratio\":1.1,\"clients\":4,\"results\":[{\"transport\":0,\"clients\":100,\"req_per_s\":9000.0},{\"transport\":1,\"clients\":100,\"req_per_s\":9100.0},{\"transport\":1,\"clients\":1000,\"req_per_s\":9050.0}]}\n",
        );
        // `clients` inside a results entry is a discriminator; the
        // top-level `clients` field stays a plain recorded metric.
        assert_eq!(c["serve_network.clients"], 4.0);
        assert_eq!(c["serve_network.many_conn_ratio"], 1.1);
        assert_eq!(c["serve_network.transport=0.clients=100.req_per_s"], 9000.0);
        assert_eq!(c["serve_network.transport=1.clients=100.req_per_s"], 9100.0);
        assert_eq!(c["serve_network.transport=1.clients=1000.req_per_s"], 9050.0);
        let base = r#"{"metrics":{"serve_network.many_conn_ratio":{"baseline":1.0,"tolerance":0.25}}}"#;
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 0);
    }

    #[test]
    fn trace_rows_discriminate_traced_vs_untraced_serving() {
        let c = current_from(
            "json: {\"bench\":\"serve_network\",\"obs_overhead_ratio\":0.99,\"results\":[{\"transport\":1,\"clients\":4,\"trace\":1,\"req_per_s\":9000.0},{\"transport\":1,\"clients\":4,\"trace\":0,\"req_per_s\":9090.0}]}\n",
        );
        assert_eq!(c["serve_network.obs_overhead_ratio"], 0.99);
        assert_eq!(c["serve_network.transport=1.clients=4.trace=1.req_per_s"], 9000.0);
        assert_eq!(c["serve_network.transport=1.clients=4.trace=0.req_per_s"], 9090.0);
        // The observability gate: traced/untraced near 1.0 passes, a
        // heavy tracing tax fails.
        let base = r#"{"metrics":{"serve_network.obs_overhead_ratio":{"baseline":1.0,"tolerance":0.05}}}"#;
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 0);
        let mut worse = c.clone();
        worse.insert("serve_network.obs_overhead_ratio".into(), 0.8);
        assert_eq!(check_against_baseline(base, &worse).unwrap().failures, 1);
    }

    #[test]
    fn shard_rows_discriminate_scatter_gather_fanout() {
        let c = current_from(
            "json: {\"bench\":\"serve_network\",\"shard_scatter_ratio\":1.05,\"results\":[{\"shards\":1,\"req_per_s\":8000.0},{\"shards\":2,\"req_per_s\":8400.0},{\"shards\":4,\"req_per_s\":8300.0}]}\n",
        );
        assert_eq!(c["serve_network.shard_scatter_ratio"], 1.05);
        assert_eq!(c["serve_network.shards=1.req_per_s"], 8000.0);
        assert_eq!(c["serve_network.shards=2.req_per_s"], 8400.0);
        assert_eq!(c["serve_network.shards=4.req_per_s"], 8300.0);
        // The fan-out gate: 2-shard scatter throughput near the 1-shard
        // proxy throughput passes; a fan-out collapse fails.
        let base = r#"{"metrics":{"serve_network.shard_scatter_ratio":{"baseline":0.75}}}"#;
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 0);
        let mut worse = c.clone();
        worse.insert("serve_network.shard_scatter_ratio".into(), 0.3);
        assert_eq!(check_against_baseline(base, &worse).unwrap().failures, 1);
    }

    #[test]
    fn multilabel_rows_discriminate_objectives() {
        let c = current_from(
            "json: {\"bench\":\"multilabel_sweep\",\"p1_gain_ml_vs_single\":0.08,\"naive_p1\":0.31,\"results\":[{\"multilabel\":0,\"p1\":0.52,\"model_bytes\":180000.0},{\"multilabel\":1,\"p1\":0.60,\"model_bytes\":180000.0},{\"multilabel\":2,\"p1\":0.59,\"model_bytes\":180000.0}]}\n",
        );
        assert_eq!(c["multilabel_sweep.p1_gain_ml_vs_single"], 0.08);
        assert_eq!(c["multilabel_sweep.naive_p1"], 0.31);
        assert_eq!(c["multilabel_sweep.multilabel=0.p1"], 0.52);
        assert_eq!(c["multilabel_sweep.multilabel=1.p1"], 0.60);
        assert_eq!(c["multilabel_sweep.multilabel=2.p1"], 0.59);
        // The refactor's payoff gate: the union loss must stay strictly
        // ahead of the singleton-degenerate run.
        let base = r#"{"metrics":{"multilabel_sweep.p1_gain_ml_vs_single":{"baseline":0.0001,"tolerance":0.0}}}"#;
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 0);
        let mut worse = c.clone();
        worse.insert("multilabel_sweep.p1_gain_ml_vs_single".into(), -0.01);
        assert_eq!(check_against_baseline(base, &worse).unwrap().failures, 1);
    }

    #[test]
    fn lower_is_better_direction() {
        let mut c = BTreeMap::new();
        c.insert("decode.viterbi_ratio".to_string(), 30.0);
        let base = r#"{"metrics":{"decode.viterbi_ratio":{"baseline":20.0,"higher_is_better":false,"tolerance":1.0}}}"#;
        // 30 ≤ 20·2 → ok.
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 0);
        c.insert("decode.viterbi_ratio".to_string(), 50.0);
        // 50 > 40 → fail.
        assert_eq!(check_against_baseline(base, &c).unwrap().failures, 1);
    }

    #[test]
    fn missing_gated_metric_fails_but_null_is_record_only() {
        let c = current_from(SAMPLE);
        let base = r#"{"metrics":{
            "decode.viterbi_ratio":{"baseline":20.0,"higher_is_better":false},
            "serve_throughput.workers=1.req_per_s":null,
            "serve_throughput.workers=9.req_per_s":null}}"#;
        let r = check_against_baseline(base, &c).unwrap();
        assert_eq!(r.failures, 1, "gated decode metric absent → fail");
        assert!(r.text.contains("record"));
        // Per-metric override of the global tolerance is honored above;
        // malformed entries error instead of silently passing.
        let bad = r#"{"metrics":{"x":{"note":"no baseline key"}}}"#;
        assert!(check_against_baseline(bad, &c).is_err());
    }
}
