#!/usr/bin/env bash
# Multi-process scatter-gather smoke (see docs/ARCHITECTURE.md §Sharded
# serving): train a tiny model, slice it into 2 label-space shards
# (`ltls shard`), serve each shard from 2 replica processes
# (`ltls serve --listen`), fan out through `ltls coordinator`, and then
# exercise the failure ladder end-to-end:
#
#   1. 200 pipelined requests against the healthy 2x2 tier — every reply
#      is a full top-k, no `"partial":true`, even though one replica of
#      shard 0 is killed mid-traffic (failover must drop nothing);
#   2. kill the remaining shard-0 replica — replies degrade to
#      `"partial":true` (shard 1's candidates only) instead of erroring;
#   3. restart a shard-0 replica — full replies resume;
#   4. METRICS shows the per-shard counters and a nonzero degraded count;
#   5. SHUTDOWN drains the coordinator and every shard server cleanly.
#
# Usage: tools/shard_smoke.sh [path-to-ltls-binary]
# (defaults to target/release/ltls, as built by `cargo build --release`).
set -euo pipefail

LTLS="${1:-${LTLS:-target/release/ltls}}"
DIR="$(mktemp -d /tmp/ltls-shard-smoke.XXXXXX)"
trap 'kill $(jobs -p) 2>/dev/null || true; rm -rf "$DIR"' EXIT

COORD_PORT=8100
S0A=8101 S0B=8102 S1A=8103 S1B=8104

wait_port() {
  for _ in $(seq 1 100); do
    if (exec 3<>"/dev/tcp/127.0.0.1/$1") 2>/dev/null; then return 0; fi
    sleep 0.2
  done
  echo "port $1 never came up" >&2
  return 1
}

echo "== train + shard =="
"$LTLS" train --dataset synthetic --epochs 1 --save "$DIR/model.ltls"
"$LTLS" shard --model "$DIR/model.ltls" --shards 2 | tee "$DIR/shard.txt"
grep -q "shard 0/2:" "$DIR/shard.txt"
test -f "$DIR/model.shard0.ltls"
test -f "$DIR/model.shard1.ltls"

echo "== start 2x2 shard tier + coordinator =="
"$LTLS" serve --listen 127.0.0.1:$S0A --model "$DIR/model.shard0.ltls" > "$DIR/s0a.log" 2>&1 &
P0A=$!
"$LTLS" serve --listen 127.0.0.1:$S0B --model "$DIR/model.shard0.ltls" > "$DIR/s0b.log" 2>&1 &
P0B=$!
"$LTLS" serve --listen 127.0.0.1:$S1A --model "$DIR/model.shard1.ltls" > "$DIR/s1a.log" 2>&1 &
P1A=$!
"$LTLS" serve --listen 127.0.0.1:$S1B --model "$DIR/model.shard1.ltls" > "$DIR/s1b.log" 2>&1 &
P1B=$!
for p in $S0A $S0B $S1A $S1B; do wait_port "$p"; done

"$LTLS" coordinator --listen 127.0.0.1:$COORD_PORT \
  --shards "127.0.0.1:$S0A,127.0.0.1:$S0B;127.0.0.1:$S1A,127.0.0.1:$S1B" \
  > "$DIR/coord.log" 2>&1 &
COORD_PID=$!
wait_port $COORD_PORT

# One persistent client connection for the whole ladder: the coordinator
# must survive every phase on the same socket.
exec 4<>/dev/tcp/127.0.0.1/$COORD_PORT

# Pipeline a burst of $1 requests, then read the replies back into $2.
burst() {
  local n=$1 out=$2 i line
  for ((i = 0; i < n; i++)); do echo "3 $((i % 7)):1.0 $((5 + i % 11)):0.5" >&4; done
  for ((i = 0; i < n; i++)); do
    read -r line <&4
    echo "$line" >>"$out"
  done
}

echo "== 200 pipelined requests, one replica killed mid-traffic =="
: >"$DIR/replies.txt"
burst 50 "$DIR/replies.txt"
kill "$P0A"
wait "$P0A" 2>/dev/null || true
burst 50 "$DIR/replies.txt"
burst 50 "$DIR/replies.txt"
burst 50 "$DIR/replies.txt"
[ "$(grep -c topk "$DIR/replies.txt")" -eq 200 ]
! grep -q '"partial":true' "$DIR/replies.txt"
echo "ok: 200/200 full replies across the replica kill"

echo "== kill the last shard-0 replica: replies degrade, not error =="
kill "$P0B"
wait "$P0B" 2>/dev/null || true
: >"$DIR/degraded.txt"
for _ in $(seq 1 10); do
  echo "3 0:1.0 5:0.5" >&4
  read -r line <&4
  echo "$line" >>"$DIR/degraded.txt"
done
[ "$(grep -c topk "$DIR/degraded.txt")" -eq 10 ]
grep -q '"partial":true' "$DIR/degraded.txt"
echo "ok: degraded replies carry \"partial\":true"

echo "== metrics: per-shard counters + degraded count =="
echo "METRICS" >&4
while read -r line <&4; do [ "$line" = "# end" ] && break; echo "$line"; done >"$DIR/metrics.txt"
grep -q 'ltls_shard_requests_total{shard="0"}' "$DIR/metrics.txt"
grep -q 'ltls_shard_requests_total{shard="1"}' "$DIR/metrics.txt"
grep -q 'ltls_shard_rtt_seconds_bucket' "$DIR/metrics.txt"
deg=$(grep '^ltls_shard_degraded_total ' "$DIR/metrics.txt" | awk '{print $2}')
[ "$deg" -ge 1 ]

echo "== restart a shard-0 replica: full replies resume =="
"$LTLS" serve --listen 127.0.0.1:$S0A --model "$DIR/model.shard0.ltls" > "$DIR/s0a2.log" 2>&1 &
P0A=$!
wait_port $S0A
recovered=0
for _ in $(seq 1 50); do
  echo "3 0:1.0 5:0.5" >&4
  read -r line <&4
  if ! echo "$line" | grep -q '"partial":true'; then
    recovered=1
    break
  fi
  sleep 0.1
done
[ "$recovered" -eq 1 ]
echo "ok: recovery observed after replica restart"

echo "== clean drain =="
echo "SHUTDOWN" >&4
read -r bye <&4
echo "$bye" | grep -q draining
exec 4>&-
wait "$COORD_PID"
grep -q "drained cleanly" "$DIR/coord.log"
for p in $S0A $S1A $S1B; do
  exec 5<>"/dev/tcp/127.0.0.1/$p"
  echo "SHUTDOWN" >&5
  read -r bye <&5
  exec 5>&-
done
wait "$P0A" "$P1A" "$P1B"
echo "shard_smoke: all phases passed"
