//! Regenerate the paper's Tables 1, 2 and 3 on the synthetic analogs.
//!
//! Run: `cargo run --release --example paper_tables -- [table1|table2|table3|all] [--scale S] [--epochs N]`
//!
//! Absolute numbers differ from the paper (our substrate is synthetic —
//! see DESIGN.md §3); the comparison *shape* is what must reproduce:
//! who wins where, the imageNet/Eur-Lex failure rows, model-size ratios.

use ltls::eval::tables;
use ltls::util::args::Args;

fn main() {
    let args = Args::from_env();
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let scale = args.get_f32("scale", 0.25) as f64;
    let epochs = args.get_usize("epochs", 5);
    let seed = args.get_u64("seed", 42);

    if matches!(which, "table1" | "all") {
        let r = tables::table1(scale, epochs, seed);
        print!("{}", r.render());
        println!("json: {}\n", r.to_json().dump());
    }
    if matches!(which, "table2" | "all") {
        let r = tables::table2(scale, epochs, seed);
        print!("{}", r.render());
        println!("json: {}\n", r.to_json().dump());
    }
    if matches!(which, "table3" | "all") {
        let rows = tables::table3(scale, epochs, seed);
        print!("{}", tables::render_table3(&rows));
    }
}
