//! The headline claim, demonstrated at truly extreme scale: LTLS
//! structures with C up to 2^30 classes decode in microseconds and the
//! model grows only logarithmically.
//!
//! Also trains end-to-end at C = 1,000,000 on synthetic data to show the
//! full pipeline (assignment policy, sparse SGD, list-Viterbi top-k)
//! works beyond any dataset the paper had.
//!
//! Run: `cargo run --release --example extreme_scale`

use ltls::data::synthetic::SyntheticSpec;
use ltls::eval::{precision_at_1, Predictor};
use ltls::graph::Trellis;
use ltls::train::{TrainConfig, Trainer};
use ltls::util::rng::Rng;
use ltls::util::timer::Timer;

fn main() {
    // --- Structure scaling: decode cost vs C --------------------------
    println!("{:<16}{:>8}{:>14}{:>14}{:>18}", "C", "E", "viterbi/op", "top-10/op", "model @ D=100k");
    let mut rng = Rng::new(1);
    for exp in [10u32, 14, 18, 22, 26, 30] {
        let c = (1u64 << exp) + 7;
        let t = Trellis::new(c);
        let h: Vec<f32> = (0..t.num_edges()).map(|_| rng.normal()).collect();
        let timer = Timer::new();
        let iters = 50_000;
        for _ in 0..iters {
            std::hint::black_box(ltls::decode::viterbi(&t, std::hint::black_box(&h)));
        }
        let v_ns = timer.elapsed_s() * 1e9 / iters as f64;
        let timer = Timer::new();
        for _ in 0..iters / 10 {
            std::hint::black_box(ltls::decode::list_viterbi(&t, std::hint::black_box(&h), 10));
        }
        let l_ns = timer.elapsed_s() * 1e9 / (iters / 10) as f64;
        println!(
            "{:<16}{:>8}{:>12.0}ns{:>12.0}ns{:>15.1} MB",
            c,
            t.num_edges(),
            v_ns,
            l_ns,
            (t.num_edges() * 100_000 * 4) as f64 / 1e6
        );
    }
    println!("(decode grows ~linearly in E = O(log C); an OVA model at C=2^30, D=100k would be 429 TB)\n");

    // --- End-to-end at C = 1M -----------------------------------------
    println!("training LTLS end-to-end at C = 1,048,576 ...");
    let c = 1 << 20;
    let ds = SyntheticSpec::multiclass(30_000, 20_000, c)
        .skew(1.05)
        .noise(0.02)
        .seed(2)
        .generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 3);
    println!("data: {}", ltls::data::stats::stats(&train));

    let timer = Timer::new();
    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    for (i, m) in tr.fit(&train, 3).into_iter().enumerate() {
        println!("epoch {}: {}", i + 1, m);
    }
    let train_s = timer.elapsed_s();
    let model = tr.into_model();
    let p1 = precision_at_1(&model, &test);
    let timing = ltls::eval::time_predictions(&model, &test, 1);
    println!(
        "\nC=2^20: p@1 = {:.4} (chance {:.6}), train {:.1}s, predict {:.1} µs/ex, model {:.1} MB (E={})",
        p1,
        1.0 / c as f64,
        train_s,
        timing.per_example_us,
        model.model_bytes() as f64 / 1e6,
        model.trellis.num_edges()
    );
    println!("an OVA model here would be {:.0} GB", (c as f64 * 20_000.0 * 4.0) / 1e9);
}
