//! Quickstart: the full LTLS story in one file.
//!
//! 1. Build the paper's Figure-1 trellis (C=22) and print it.
//! 2. Show the Figure-2 update-trace semantics (symmetric difference).
//! 3. Train LTLS on a small synthetic extreme-classification problem,
//!    evaluate precision@1, and demonstrate log-space model size.
//!
//! Run: `cargo run --release --example quickstart`

use ltls::data::synthetic::SyntheticSpec;
use ltls::eval::{precision_at_1, Predictor};
use ltls::graph::{dot, Trellis};
use ltls::train::{TrainConfig, Trainer};

fn main() {
    // --- Figure 1: the trellis for C=22 ------------------------------
    let t = Trellis::new(22);
    println!("{}", dot::to_ascii(&t));
    println!("Graphviz (paths for labels 3=green / 17=red highlighted):\n");
    println!("{}", dot::to_dot(&t, &[(3, "green"), (17, "red")]));

    // --- Figure 2: update semantics ----------------------------------
    println!("{}", dot::update_trace(&t, 3, 17));

    // --- Train on a synthetic problem --------------------------------
    let ds = SyntheticSpec::multiclass(4000, 2000, 128).noise(0.02).seed(1).generate();
    let (train, test) = ltls::data::split::random_split(&ds, 0.2, 1);
    println!("dataset: {}", ltls::data::stats::stats(&train));

    let mut trainer = Trainer::new(TrainConfig::default(), ds.n_features, ds.n_labels);
    for (i, m) in trainer.fit(&train, 5).into_iter().enumerate() {
        println!("epoch {}: {}", i + 1, m);
    }
    let model = trainer.into_model();
    let p1 = precision_at_1(&model, &test);
    println!("\nprecision@1 = {p1:.4}");

    // --- The log-space claim ------------------------------------------
    let e = model.trellis.num_edges();
    println!(
        "model: E = {} edges for C = {} classes -> {} weights ({:.2} MB); an OVA model would need {} ({:.2} MB)",
        e,
        ds.n_labels,
        e * ds.n_features,
        model.model_bytes() as f64 / 1e6,
        ds.n_labels * ds.n_features,
        (ds.n_labels * ds.n_features * 4) as f64 / 1e6,
    );

    // --- Top-k prediction ----------------------------------------------
    let top = model.topk(test.row(0), 5);
    println!("top-5 for test example 0 (true = {:?}): {:?}", test.labels_of(0), top);
}
