//! Serving driver: train LTLS on the aloi analog, stand up the batching
//! multi-worker prediction server (batched edge scoring + per-worker
//! engine scratchpads), and drive a closed-loop load test, reporting
//! throughput and latency percentiles (the L3 coordinator's perf story).
//!
//! Run: `cargo run --release --example serve_batched -- [--requests N] [--batch B] [--max-wait-us U] [--clients T] [--workers W]`
//! (`--workers 0`, the default, sizes the pool to the available cores)

use ltls::coordinator::{BatchedLtls, BatcherConfig, PredictServer, ServerConfig};
use ltls::data::datasets;
use ltls::eval::{precision_at_1, Predictor};
use ltls::train::{TrainConfig, Trainer};
use ltls::util::args::Args;
use ltls::util::timer::Timer;
use std::sync::Arc;

fn main() {
    let args = Args::from_env();
    let n_requests = args.get_usize("requests", 50_000);
    let max_batch = args.get_usize("batch", 64);
    let max_wait_us = args.get_u64("max-wait-us", 300);
    let clients = args.get_usize("clients", 4);
    let workers = args.get_usize("workers", 0);

    let analog = datasets::by_name("aloi.bin").unwrap_or_else(|| {
        eprintln!("error: unknown dataset \"aloi.bin\" (dataset registry renamed?)");
        std::process::exit(1);
    });
    let (train, test) = analog.generate(0.2, 5);
    println!("data: {}", ltls::data::stats::stats(&train));

    let mut tr = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    tr.fit(&train, 4);
    let model = tr.into_model();
    println!(
        "model: p@1 = {:.4}, {:.2} MB, E = {}",
        precision_at_1(&model, &test),
        model.model_bytes() as f64 / 1e6,
        model.trellis.num_edges()
    );

    let server = Arc::new(PredictServer::start(
        BatchedLtls(model),
        ServerConfig {
            batcher: BatcherConfig {
                max_batch,
                max_wait: std::time::Duration::from_micros(max_wait_us),
            },
            queue_depth: 2048,
            workers,
        },
    ));
    println!("server: {} workers (batched LTLS path)", server.n_workers());

    // Closed-loop clients, each with a small pipeline window.
    let test = Arc::new(test);
    let timer = Timer::new();
    let per_client = n_requests / clients;
    let handles: Vec<_> = (0..clients)
        .map(|cid| {
            let server = Arc::clone(&server);
            let test = Arc::clone(&test);
            std::thread::spawn(move || {
                let mut pending = std::collections::VecDeque::new();
                for i in 0..per_client {
                    let row = test.row((cid * per_client + i) % test.n_examples());
                    pending.push_back(server.submit(
                        row.indices.to_vec(),
                        row.values.to_vec(),
                        1,
                    ));
                    if pending.len() >= 32 {
                        pending.pop_front().unwrap().recv().unwrap();
                    }
                }
                for rx in pending {
                    rx.recv().unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let secs = timer.elapsed_s();

    println!("\n==== serving metrics ====");
    println!("{}", server.metrics.summary());
    println!(
        "throughput: {:.0} req/s over {} requests ({} clients, {} workers, batch<= {max_batch}, wait {max_wait_us}us)",
        (per_client * clients) as f64 / secs,
        per_client * clients,
        clients,
        server.n_workers(),
    );
    let p50 = server.metrics.request_quantile_ns(0.5) / 1e3;
    let p99 = server.metrics.request_quantile_ns(0.99) / 1e3;
    println!("request latency p50 {p50:.0}us  p99 {p99:.0}us  (p99/p50 = {:.1})", p99 / p50.max(1.0));
}
