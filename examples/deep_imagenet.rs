//! END-TO-END VALIDATION DRIVER (DESIGN.md §4, paper §6).
//!
//! Reproduces the paper's deep-network ImageNet experiment across all
//! three layers with Python nowhere on the path:
//!
//! * L1 — the Pallas kernels (tiled edge-score matmul + trellis Viterbi)
//!   inside the AOT artifacts;
//! * L2 — the JAX MLP (2×500 ReLU, the paper's architecture) and its
//!   trellis-softmax SGD train step, lowered once by `make artifacts`;
//! * L3 — this rust driver: data pipeline, training loop, evaluation, and
//!   the baseline comparison (linear LTLS trained in rust).
//!
//! The paper reports linear LTLS collapsing to 0.0075 p@1 on ImageNet (*)
//! while the deep variant reaches 0.0507 after 10 iterations. The analog
//! here reproduces that *shape*: linear ≈ chance-level, deep ≫ linear.
//!
//! Run: `make artifacts && cargo run --release --example deep_imagenet -- [--epochs N] [--steps N]`
//! (steps caps total SGD steps for quick runs; 0 = no cap)

use ltls::data::datasets;
use ltls::eval::precision_at_1;
use ltls::runtime::{artifacts, ArtifactMeta, DeepLtls, Engine};
use ltls::train::{TrainConfig, Trainer};
use ltls::util::args::Args;
use ltls::util::rng::Rng;
use ltls::util::timer::Timer;

fn main() -> Result<(), String> {
    let args = Args::from_env();
    let epochs = args.get_usize("epochs", 4);
    let step_cap = args.get_usize("steps", 0);
    let lr = args.get_f32("lr", 0.4);
    let scale = args.get_f32("scale", 1.0) as f64;

    let meta = ArtifactMeta::load(&artifacts::default_dir())?;
    println!(
        "artifacts: C={} D={} hidden={} batch={} E={} (trellis layout cross-checked)",
        meta.c, meta.d, meta.hidden, meta.batch, meta.e
    );

    // The imageNet analog: dense features (30.8% like the real thing),
    // nonlinear teacher — exactly the regime where linear LTLS fails.
    let analog = datasets::by_name("imageNet")
        .ok_or("unknown dataset \"imageNet\" (dataset registry renamed?)")?;
    let (train, test) = analog.generate(scale, 7);
    println!("data: {}", ltls::data::stats::stats(&train));

    // --- Baseline: linear LTLS (the paper's * row) --------------------
    let t0 = Timer::new();
    let mut linear = Trainer::new(TrainConfig::default(), train.n_features, train.n_labels);
    linear.fit(&train, 3);
    let linear_model = linear.into_model();
    let linear_p1 = precision_at_1(&linear_model, &test);
    println!(
        "\n[linear LTLS]  p@1 = {:.4}  ({:.1}s train)  — the paper's failure row (*)",
        linear_p1,
        t0.elapsed_s()
    );

    // --- Deep LTLS through the AOT PJRT artifacts ---------------------
    let engine = Engine::cpu()?;
    println!("[deep LTLS]    PJRT platform: {}", engine.platform());
    let mut deep = DeepLtls::load(&engine, meta.clone())?;
    println!(
        "[deep LTLS]    {} params, LTLS output layer decodes E={} -> C={}",
        deep.param_count(),
        meta.e,
        meta.c
    );

    let b = meta.batch;
    let mut order: Vec<usize> = (0..train.n_examples()).collect();
    let mut rng = Rng::new(3);
    let mut steps = 0usize;
    let t1 = Timer::new();
    println!("\nstep, mean_loss, test_p@1   (loss curve for EXPERIMENTS.md)");
    'outer: for epoch in 0..epochs {
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0;
        let mut seen = 0usize;
        for chunk in order.chunks(b) {
            loss_sum += deep.train_batch(&train, chunk, lr)? as f64;
            seen += 1;
            steps += 1;
            if seen % 50 == 0 {
                println!("  step {:>5}: loss {:.4}", steps, loss_sum / seen as f64);
            }
            if step_cap > 0 && steps >= step_cap {
                break 'outer;
            }
        }
        let p1 = deep.precision_at_1(&test)?;
        println!(
            "epoch {:>2}: mean loss {:.4}  test p@1 {:.4}  ({:.0}s elapsed)",
            epoch + 1,
            loss_sum / seen.max(1) as f64,
            p1,
            t1.elapsed_s()
        );
    }

    let deep_p1 = deep.precision_at_1(&test)?;
    println!("\n==== paper §6 shape check ====");
    println!("linear LTLS p@1 = {linear_p1:.4}   (paper: 0.0075 on real ImageNet)");
    println!("deep   LTLS p@1 = {deep_p1:.4}   (paper: 0.0507 after 10 iterations)");
    let ratio = deep_p1 / linear_p1.max(1e-6);
    println!("deep/linear ratio = {ratio:.1}x   (paper: ~6.8x)");
    if deep_p1 > linear_p1 {
        println!("REPRODUCED: the deep edge scorer rescues the dense regime.");
    } else {
        println!("WARNING: deep did not beat linear at this scale; raise --epochs.");
    }
    Ok(())
}
