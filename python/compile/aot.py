"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: the
``xla`` crate's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit ids);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md and DESIGN.md §5.

Artifacts (shapes fixed at lower time, recorded in meta.json):

* ``mlp_fwd.hlo.txt``        (x)              -> (h,)           edge scores
* ``mlp_train_step.hlo.txt`` (params..., x, s, lr) -> (params'..., loss)
* ``ltls_infer.hlo.txt``     (params..., x)   -> (labels, scores)
* ``edge_scores.hlo.txt``    (x, w, b)        -> (h,)   bare Pallas matmul
* ``meta.json``              shapes + trellis layout fingerprint

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import functools
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MlpParams, infer, init_params, mlp_edge_scores, sgd_train_step
from .trellis import Trellis

# Problem size: the imageNet analog of the paper's §6 deep experiment.
DEFAULT = dict(c=1000, d=1000, hidden=500, batch=64)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def param_specs(d, hidden, e):
    f32 = jnp.float32
    return MlpParams(
        w1=jax.ShapeDtypeStruct((d, hidden), f32),
        b1=jax.ShapeDtypeStruct((hidden,), f32),
        w2=jax.ShapeDtypeStruct((hidden, hidden), f32),
        b2=jax.ShapeDtypeStruct((hidden,), f32),
        w3=jax.ShapeDtypeStruct((hidden, e), f32),
        b3=jax.ShapeDtypeStruct((e,), f32),
    )


def lower_all(c: int, d: int, hidden: int, batch: int):
    """Lower every artifact; returns {name: hlo_text} plus metadata."""
    t = Trellis(c)
    e = t.num_edges
    f32 = jnp.float32
    x_spec = jax.ShapeDtypeStruct((batch, d), f32)
    s_spec = jax.ShapeDtypeStruct((batch, e), f32)
    lr_spec = jax.ShapeDtypeStruct((), f32)
    params = param_specs(d, hidden, e)

    out = {}

    # mlp_fwd: params are runtime inputs so rust can stream updated weights.
    def fwd(w1, b1, w2, b2, w3, b3, x):
        return (mlp_edge_scores(MlpParams(w1, b1, w2, b2, w3, b3), x),)

    out["mlp_fwd"] = to_hlo_text(jax.jit(fwd).lower(*params, x_spec))

    # train step: flat param signature; donation happens implicitly on the
    # rust side by dropping old buffers after each step.
    def step(w1, b1, w2, b2, w3, b3, x, s, lr):
        new, loss = sgd_train_step(t, MlpParams(w1, b1, w2, b2, w3, b3), x, s, lr)
        return (*new, loss)

    out["mlp_train_step"] = to_hlo_text(
        jax.jit(step).lower(*params, x_spec, s_spec, lr_spec)
    )

    # full inference: MLP + Pallas viterbi in one program.
    def full_infer(w1, b1, w2, b2, w3, b3, x):
        labels, scores = infer(t, MlpParams(w1, b1, w2, b2, w3, b3), x)
        return (labels, scores)

    out["ltls_infer"] = to_hlo_text(jax.jit(full_infer).lower(*params, x_spec))

    # bare Pallas edge-score matmul (kernel-level artifact, also used by
    # the runtime microbenches).
    from .kernels.edge_scores import edge_scores

    w_spec = jax.ShapeDtypeStruct((d, e), f32)
    b_spec = jax.ShapeDtypeStruct((e,), f32)

    def bare(x, w, b):
        return (edge_scores(x, w, b),)

    out["edge_scores"] = to_hlo_text(jax.jit(bare).lower(x_spec, w_spec, b_spec))

    meta = {
        "c": c,
        "d": d,
        "hidden": hidden,
        "batch": batch,
        "e": e,
        "trellis": t.layout_fingerprint(),
        "artifacts": {
            "mlp_fwd": {
                "inputs": ["w1", "b1", "w2", "b2", "w3", "b3", "x"],
                "outputs": ["h"],
            },
            "mlp_train_step": {
                "inputs": ["w1", "b1", "w2", "b2", "w3", "b3", "x", "s", "lr"],
                "outputs": ["w1", "b1", "w2", "b2", "w3", "b3", "loss"],
            },
            "ltls_infer": {
                "inputs": ["w1", "b1", "w2", "b2", "w3", "b3", "x"],
                "outputs": ["labels", "scores"],
            },
            "edge_scores": {"inputs": ["x", "w", "b"], "outputs": ["h"]},
        },
        "param_shapes": {
            "w1": [d, hidden],
            "b1": [hidden],
            "w2": [hidden, hidden],
            "b2": [hidden],
            "w3": [hidden, e],
            "b3": [e],
        },
    }
    return out, meta


def write_init_params(path: str, c: int, d: int, hidden: int, seed: int = 0):
    """Dump He-initialized params as raw little-endian f32 (one file per
    tensor) so the rust driver starts from the same init as python."""
    t = Trellis(c)
    # The rust data pipeline L2-normalizes inputs — scale w1 accordingly.
    params = init_params(jax.random.PRNGKey(seed), d, hidden, t.num_edges,
                         normalized_inputs=True)
    os.makedirs(path, exist_ok=True)
    import numpy as np

    for name, arr in params._asdict().items():
        np.asarray(arr, dtype="<f4").tofile(os.path.join(path, f"{name}.f32"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--c", type=int, default=DEFAULT["c"])
    ap.add_argument("--d", type=int, default=DEFAULT["d"])
    ap.add_argument("--hidden", type=int, default=DEFAULT["hidden"])
    ap.add_argument("--batch", type=int, default=DEFAULT["batch"])
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    hlos, meta = lower_all(args.c, args.d, args.hidden, args.batch)
    for name, text in hlos.items():
        p = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(p, "w") as f:
            f.write(text)
        print(f"wrote {p} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    write_init_params(os.path.join(args.out_dir, "init_params"),
                      args.c, args.d, args.hidden)
    print(f"wrote {args.out_dir}/meta.json and init_params/")
    return 0


if __name__ == "__main__":
    sys.exit(main())
