"""LTLS trellis structure — python twin of ``rust/src/graph/``.

The edge layout here MUST match the rust implementation bit-for-bit (the
AOT artifacts bake this structure into HLO, and the rust runtime
cross-checks the layout recorded in ``artifacts/meta.json`` against its own
trellis at load time).

Layout for C classes, ``b = floor(log2(C))`` steps:

* edges 0..1                      source -> (step1, state s)
* edges 2 + 4*(j-2) + 2a + t      (step j-1, a) -> (step j, t), j in 2..=b
* edges 2 + 4*(b-1) + s           (step b, s) -> auxiliary
* edge  2 + 4*(b-1) + 2           auxiliary -> sink
* then one early-exit edge (step i+1, state 1) -> sink per set bit i < b
  of C, ascending.

``E = 4*b + popcount(C)``.
"""

from dataclasses import dataclass, field
from typing import List


def floor_log2(c: int) -> int:
    assert c >= 1
    return c.bit_length() - 1


@dataclass
class Trellis:
    """Trellis for ``c`` classes (c >= 2)."""

    c: int
    steps: int = field(init=False)
    exit_bits: List[int] = field(init=False)

    def __post_init__(self) -> None:
        assert self.c >= 2, "LTLS needs at least 2 classes"
        self.steps = floor_log2(self.c)
        self.exit_bits = [i for i in range(self.steps) if (self.c >> i) & 1]

    # -- edge indexing (mirrors rust O(1) arithmetic) --

    @property
    def num_edges(self) -> int:
        return 4 * self.steps + bin(self.c).count("1")

    def source_edge(self, s: int) -> int:
        return s

    def transition_edge(self, j: int, a: int, t: int) -> int:
        assert 2 <= j <= self.steps
        return 2 + 4 * (j - 2) + 2 * a + t

    def _aux_base(self) -> int:
        return 2 + 4 * (self.steps - 1)

    def aux_edge(self, s: int) -> int:
        return self._aux_base() + s

    def aux_sink_edge(self) -> int:
        return self._aux_base() + 2

    def exit_edge(self, rank: int) -> int:
        return self._aux_base() + 3 + rank

    def exit_rank(self, bit: int) -> int:
        return self.exit_bits.index(bit)

    def exit_label_base(self, rank: int) -> int:
        base = 1 << self.steps
        for k in range(rank):
            base += 1 << self.exit_bits[k]
        return base

    # -- path codec (canonical label <-> path) --

    def path_states(self, label: int):
        """(states, exit_bit|None) for a canonical label index."""
        assert 0 <= label < self.c
        full = 1 << self.steps
        if label < full:
            return [(label >> j) & 1 for j in range(self.steps)], None
        r = label - full
        for k, bit in enumerate(self.exit_bits):
            cnt = 1 << bit
            if r < cnt:
                states = [(r >> j) & 1 for j in range(bit)] + [1]
                return states, bit
            r -= cnt
        raise AssertionError("unreachable")

    def edges_of_label(self, label: int) -> List[int]:
        states, exit_bit = self.path_states(label)
        out = [self.source_edge(states[0])]
        for j in range(2, len(states) + 1):
            out.append(self.transition_edge(j, states[j - 2], states[j - 1]))
        if exit_bit is None:
            out.append(self.aux_edge(states[-1]))
            out.append(self.aux_sink_edge())
        else:
            out.append(self.exit_edge(self.exit_rank(exit_bit)))
        return out

    def path_matrix(self):
        """Dense M_G in {0,1}^{C x E} (small C only — test oracle)."""
        import numpy as np

        m = np.zeros((self.c, self.num_edges), dtype=np.float32)
        for l in range(self.c):
            for e in self.edges_of_label(l):
                m[l, e] = 1.0
        return m

    def layout_fingerprint(self) -> dict:
        """Structure summary recorded in meta.json for the rust cross-check."""
        return {
            "c": self.c,
            "steps": self.steps,
            "num_edges": self.num_edges,
            "exit_bits": list(self.exit_bits),
            "aux_sink_edge": self.aux_sink_edge(),
        }
