"""L2: the deep LTLS variant in JAX (paper §6 ImageNet experiment).

A 2-layer MLP (ReLU, 500 hidden units each — the paper's architecture)
produces the E edge scores; LTLS is the output layer, decoding E scores to
C classes. Training uses the trellis softmax (multinomial logistic whose
log-partition function the trellis computes in O(E), §5); gradients flow
through the edge-score vector by JAX autodiff — the forward-backward
algorithm emerges from differentiating the forward DP.

Everything here is build-time only: ``aot.py`` lowers these functions to
HLO text once, and the rust runtime executes them on the request path.
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.edge_scores import tiled_matmul
from .trellis import Trellis


class MlpParams(NamedTuple):
    """Parameters of the deep edge scorer (D -> H -> H -> E)."""

    w1: jax.Array
    b1: jax.Array
    w2: jax.Array
    b2: jax.Array
    w3: jax.Array
    b3: jax.Array


def init_params(key, d: int, h: int, e: int, normalized_inputs: bool = False) -> MlpParams:
    """He-initialized MLP parameters.

    ``normalized_inputs=True`` rescales the first layer for L2-normalized
    inputs (‖x‖ = 1): classic He init assumes per-coordinate unit variance
    (‖x‖ ≈ √D), and with unit-norm rows the first-layer activations would
    be ~√D too small — gradients vanish and the trellis softmax plateaus
    at log C (measured in EXPERIMENTS.md §6).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    w1_scale = (2.0 / d) ** 0.5 * (d ** 0.5 if normalized_inputs else 1.0)
    return MlpParams(
        w1=jax.random.normal(k1, (d, h), jnp.float32) * w1_scale,
        b1=jnp.zeros((h,), jnp.float32),
        w2=jax.random.normal(k2, (h, h), jnp.float32) * (2.0 / h) ** 0.5,
        b2=jnp.zeros((h,), jnp.float32),
        w3=jax.random.normal(k3, (h, e), jnp.float32) * (2.0 / h) ** 0.5,
        b3=jnp.zeros((e,), jnp.float32),
    )


def mlp_edge_scores(params: MlpParams, x, use_pallas: bool = True):
    """Edge scores h(w, x): (B, D) -> (B, E).

    The first (widest) matmul runs on the L1 Pallas kernel; the small tail
    matmuls use jnp directly (they lower to the same dot HLO).
    """
    mm = tiled_matmul if use_pallas else jnp.matmul
    h1 = jax.nn.relu(mm(x, params.w1) + params.b1)
    h2 = jax.nn.relu(jnp.matmul(h1, params.w2) + params.b2)
    return jnp.matmul(h2, params.w3) + params.b3


def trellis_log_partition(t: Trellis, h):
    """log Σ_paths exp(score) for a batch of edge-score rows h (B, E).

    The forward algorithm over the trellis, unrolled over the static
    structure — O(E) ops, differentiable (its gradient is the posterior
    edge-marginal vector, i.e. forward-backward via autodiff).
    """
    a0 = h[:, t.source_edge(0)]
    a1 = h[:, t.source_edge(1)]
    terms = []
    exit_rank = 0
    if t.exit_bits and t.exit_bits[0] == 0:
        terms.append(a1 + h[:, t.exit_edge(0)])
        exit_rank = 1
    for j in range(2, t.steps + 1):
        n0 = jnp.logaddexp(a0 + h[:, t.transition_edge(j, 0, 0)],
                           a1 + h[:, t.transition_edge(j, 1, 0)])
        n1 = jnp.logaddexp(a0 + h[:, t.transition_edge(j, 0, 1)],
                           a1 + h[:, t.transition_edge(j, 1, 1)])
        a0, a1 = n0, n1
        if exit_rank < len(t.exit_bits) and t.exit_bits[exit_rank] == j - 1:
            terms.append(a1 + h[:, t.exit_edge(exit_rank)])
            exit_rank += 1
    aux = h[:, t.aux_sink_edge()]
    terms.append(a0 + h[:, t.aux_edge(0)] + aux)
    terms.append(a1 + h[:, t.aux_edge(1)] + aux)
    stacked = jnp.stack(terms, axis=0)  # (n_terms, B)
    mx = stacked.max(axis=0)
    return mx + jnp.log(jnp.sum(jnp.exp(stacked - mx[None, :]), axis=0))


def trellis_softmax_loss(t: Trellis, params: MlpParams, x, s):
    """Mean NLL of the true paths.

    ``s`` is the (B, E) path-indicator matrix of the true labels (rows of
    M_G, built by the caller — the rust side uses its codec, tests use
    ``Trellis.edges_of_label``).
    """
    h = mlp_edge_scores(params, x)
    logz = trellis_log_partition(t, h)
    score = jnp.sum(s * h, axis=1)
    return jnp.mean(logz - score)


def sgd_train_step(t: Trellis, params: MlpParams, x, s, lr):
    """One SGD step; returns (new_params, loss). AOT'd with donated params."""
    loss, grads = jax.value_and_grad(
        lambda p: trellis_softmax_loss(t, p, x, s)
    )(params)
    new = MlpParams(*(p - lr * g for p, g in zip(params, grads)))
    return new, loss


def infer(t: Trellis, params: MlpParams, x):
    """Batched top-1 inference: (labels int32 (B,), scores (B,)).

    Runs the MLP and the L1 Pallas Viterbi kernel — the full dense
    prediction path that the rust coordinator calls as one HLO program.
    """
    from .kernels.viterbi import viterbi_decode

    h = mlp_edge_scores(params, x)
    return viterbi_decode(h, t.c)


def make_jitted(c: int, d: int, hidden: int):
    """Convenience bundle of jitted fns for a given problem size."""
    t = Trellis(c)
    e = t.num_edges
    step = jax.jit(functools.partial(sgd_train_step, t))
    fwd = jax.jit(functools.partial(mlp_edge_scores))
    dec = jax.jit(functools.partial(infer, t))
    return t, e, step, fwd, dec
