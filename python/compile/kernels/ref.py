"""Pure-jnp oracles for the Pallas kernels (correctness references).

Every kernel in this package is pytest-compared against these functions
(exactly in interpret mode, to float tolerance after AOT round-trips).
"""

import jax.numpy as jnp

from ..trellis import Trellis


def matmul_ref(x, w):
    """Reference for kernels.edge_scores.tiled_matmul: plain X @ W."""
    return jnp.matmul(x, w)


def edge_scores_ref(x, w, b):
    """Reference edge-score layer: X @ W + b (W is D x E)."""
    return jnp.matmul(x, w) + b


def viterbi_ref(t: Trellis, h):
    """Reference decode: dense M_G argmax. h is (B, E).

    Returns (labels int32 (B,), scores f32 (B,)). Ties break to the
    smaller label (jnp.argmax semantics), matching the rust oracle.
    """
    m = jnp.asarray(t.path_matrix())  # (C, E)
    scores = h @ m.T  # (B, C)
    labels = jnp.argmax(scores, axis=1).astype(jnp.int32)
    best = jnp.max(scores, axis=1)
    return labels, best


def log_partition_ref(t: Trellis, h):
    """Reference log-partition: logsumexp over all C path scores."""
    m = jnp.asarray(t.path_matrix())
    scores = h @ m.T  # (B, C)
    mx = scores.max(axis=1)
    return jnp.log(jnp.sum(jnp.exp(scores - mx[:, None]), axis=1)) + mx
