"""L1 Pallas kernel: batched trellis Viterbi decode.

The trellis DP has only two states per step, so the kernel keeps the whole
DP state as two (block,) vectors and fills the VPU lanes with the *batch*
dimension — the TPU adaptation of what a GPU implementation would do with
one thread per example (DESIGN.md §Hardware-Adaptation). The ≤ floor(log2 C)
steps are unrolled at trace time (the structure is static per C), so the
lowered HLO is a flat chain of vectorized selects.

Outputs the canonical path label (int32) and its score per example,
matching ``rust/src/decode/viterbi.rs`` semantics (ties measure-zero under
continuous scores).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..trellis import Trellis


def _viterbi_kernel(h_ref, label_ref, score_ref, *, t: Trellis):
    h = h_ref[...]  # (block, E)
    b = t.steps

    # DP state over the batch block: score/code per trellis state.
    s0 = h[:, t.source_edge(0)]
    s1 = h[:, t.source_edge(1)]
    c0 = jnp.zeros_like(s0, dtype=jnp.int32)
    c1 = jnp.ones_like(c0)

    best_score = jnp.full_like(s0, -jnp.inf)
    best_label = jnp.zeros_like(c0)

    def consider(cand_s, cand_l, best_s, best_l):
        take = cand_s > best_s
        return jnp.where(take, cand_s, best_s), jnp.where(take, cand_l, best_l)

    exit_rank = 0
    if t.exit_bits and t.exit_bits[0] == 0:
        lbl = t.exit_label_base(0)
        cand = s1 + h[:, t.exit_edge(0)]
        best_score, best_label = consider(
            cand, jnp.full_like(best_label, lbl), best_score, best_label
        )
        exit_rank = 1

    for j in range(2, b + 1):
        e00 = h[:, t.transition_edge(j, 0, 0)]
        e01 = h[:, t.transition_edge(j, 0, 1)]
        e10 = h[:, t.transition_edge(j, 1, 0)]
        e11 = h[:, t.transition_edge(j, 1, 1)]
        to0_a = s0 + e00
        to0_b = s1 + e10
        n0 = jnp.maximum(to0_a, to0_b)
        nc0 = jnp.where(to0_a >= to0_b, c0, c1)
        to1_a = s0 + e01
        to1_b = s1 + e11
        n1 = jnp.maximum(to1_a, to1_b)
        bitj = jnp.int32(1 << (j - 1))
        nc1 = jnp.where(to1_a >= to1_b, c0, c1) | bitj
        s0, s1, c0, c1 = n0, n1, nc0, nc1

        if exit_rank < len(t.exit_bits) and t.exit_bits[exit_rank] == j - 1:
            base = t.exit_label_base(exit_rank)
            cand = s1 + h[:, t.exit_edge(exit_rank)]
            lbl = (c1 & ~bitj) + jnp.int32(base)
            best_score, best_label = consider(cand, lbl, best_score, best_label)
            exit_rank += 1

    aux_sink = h[:, t.aux_sink_edge()]
    full0 = s0 + h[:, t.aux_edge(0)] + aux_sink
    full1 = s1 + h[:, t.aux_edge(1)] + aux_sink
    best_score, best_label = consider(full0, c0, best_score, best_label)
    best_score, best_label = consider(full1, c1, best_score, best_label)

    label_ref[...] = best_label
    score_ref[...] = best_score


def viterbi_decode(h, c: int, block: int = 128):
    """Batched Viterbi decode of edge scores ``h`` (B, E) for C classes.

    Returns (labels int32 (B,), scores f32 (B,)).
    """
    t = Trellis(c)
    b_sz, e = h.shape
    assert e == t.num_edges, f"edge dim {e} != {t.num_edges}"
    pad = (-b_sz) % block
    hp = jnp.pad(h, ((0, pad), (0, 0))) if pad else h
    bp = hp.shape[0]
    labels, scores = pl.pallas_call(
        functools.partial(_viterbi_kernel, t=t),
        grid=(bp // block,),
        in_specs=[pl.BlockSpec((block, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp,), jnp.int32),
            jax.ShapeDtypeStruct((bp,), jnp.float32),
        ],
        interpret=True,
    )(hp)
    return labels[:b_sz], scores[:b_sz]
