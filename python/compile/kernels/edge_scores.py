"""L1 Pallas kernel: tiled matmul for the dense edge-score hot spot.

The deep variant's layers are tall-skinny matmuls (batch x D times
D x H / H x E). On TPU the right schedule tiles the batch and contraction
dimensions into VMEM-resident blocks that feed the MXU, accumulating into
an output block that is revisited across the contraction grid axis — the
BlockSpec below expresses exactly that HBM<->VMEM schedule (see DESIGN.md
§Hardware-Adaptation for the GPU-paper -> TPU mapping rationale).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, which is what the AOT
artifacts ship. On a real TPU the same kernel compiles natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """One (bm, bn) output block; grid axis 2 walks the contraction."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU-shaped block product, accumulated in f32.
    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, axis: int, mult: int):
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn"))
def _tiled_matmul_impl(x, w, bm: int = 32, bk: int = 128, bn: int = 128):
    b, d = x.shape
    d2, n = w.shape
    assert d == d2, f"contraction mismatch {d} vs {d2}"
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    bp, dp = xp.shape
    np_ = wp.shape[1]
    grid = (bp // bm, np_ // bn, dp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:b, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def tiled_matmul(x, w, bm: int = 32, bk: int = 128, bn: int = 128):
    """``x @ w`` via the Pallas kernel, padding ragged edges.

    x: (B, D) f32, w: (D, N) f32 -> (B, N) f32.
    Block sizes are VMEM-budgeted: bm*bk + bk*bn + bm*bn floats
    (32*128 + 128*128 + 32*128 = 24.5k f32 = 96 KiB << 16 MiB VMEM),
    leaving headroom for double buffering.

    Differentiable: the custom VJP keeps both backward matmuls on the same
    Pallas kernel (interpret-mode pallas_call has no autodiff rule of its
    own), so the AOT'd train step's HLO contains the kernel's schedule for
    forward and backward alike.
    """
    return _tiled_matmul_impl(x, w, bm=bm, bk=bk, bn=bn)


def _tm_fwd(x, w, bm, bk, bn):
    return _tiled_matmul_impl(x, w, bm=bm, bk=bk, bn=bn), (x, w)


def _tm_bwd(bm, bk, bn, res, g):
    x, w = res
    # dx = g @ wᵀ, dw = xᵀ @ g — same kernel, transposed operands.
    dx = _tiled_matmul_impl(g, w.T, bm=bm, bk=bk, bn=bn)
    dw = _tiled_matmul_impl(x.T, g, bm=bm, bk=bk, bn=bn)
    return dx, dw


tiled_matmul.defvjp(_tm_fwd, _tm_bwd)


def edge_scores(x, w, bias, **kw):
    """Edge-score layer ``x @ w + bias`` on the Pallas matmul."""
    return tiled_matmul(x, w, **kw) + bias
