"""L2 model tests: trellis log-partition vs dense oracle, loss/grad
behavior, and a small end-to-end training sanity run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref
from compile.trellis import Trellis


def path_indicator(t, labels):
    s = np.zeros((len(labels), t.num_edges), np.float32)
    for i, l in enumerate(labels):
        for e in t.edges_of_label(int(l)):
            s[i, e] = 1.0
    return jnp.asarray(s)


@pytest.mark.parametrize("c", [2, 3, 22, 105, 159])
def test_log_partition_matches_oracle(c):
    t = Trellis(c)
    h = jax.random.normal(jax.random.PRNGKey(c), (16, t.num_edges), jnp.float32)
    got = M.trellis_log_partition(t, h)
    want = ref.log_partition_ref(t, h)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_loss_is_positive_and_decreases_with_boost():
    c, d, hid = 22, 30, 16
    t = Trellis(c)
    params = M.init_params(jax.random.PRNGKey(0), d, hid, t.num_edges)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, d), jnp.float32)
    labels = np.arange(8) % c
    s = path_indicator(t, labels)
    loss = M.trellis_softmax_loss(t, params, x, s)
    assert float(loss) > 0.0
    # NLL is at most log C at init-ish scale and must beat random guessing
    # after a few steps.
    p, lr = params, jnp.float32(0.5)
    for _ in range(30):
        p, l2 = M.sgd_train_step(t, p, x, s, lr)
    assert float(l2) < float(loss), f"{l2} !< {loss}"


def test_grad_matches_posterior_semantics():
    """d logZ / dh at the source edges sums to 1 (probability mass)."""
    c = 105
    t = Trellis(c)
    h = jax.random.normal(jax.random.PRNGKey(3), (4, t.num_edges), jnp.float32)
    g = jax.grad(lambda hh: M.trellis_log_partition(t, hh).sum())(h)
    src = g[:, t.source_edge(0)] + g[:, t.source_edge(1)]
    np.testing.assert_allclose(src, np.ones(4), rtol=1e-4, atol=1e-4)
    # terminal cut too: aux_sink + exits = 1
    term = g[:, t.aux_sink_edge()]
    for k in range(len(t.exit_bits)):
        term = term + g[:, t.exit_edge(k)]
    np.testing.assert_allclose(term, np.ones(4), rtol=1e-4, atol=1e-4)


def test_infer_consistent_with_fwd_plus_ref():
    c, d, hid = 64, 20, 12
    t = Trellis(c)
    params = M.init_params(jax.random.PRNGKey(4), d, hid, t.num_edges)
    x = jax.random.normal(jax.random.PRNGKey(5), (10, d), jnp.float32)
    labels, scores = M.infer(t, params, x)
    h = M.mlp_edge_scores(params, x)
    want_l, want_s = ref.viterbi_ref(t, h)
    np.testing.assert_array_equal(labels, want_l)
    np.testing.assert_allclose(scores, want_s, rtol=1e-4, atol=1e-4)


def test_training_learns_toy_problem():
    """End-to-end: the deep model overfits 64 fixed examples quickly."""
    c, d, hid, b = 32, 16, 32, 64
    t = Trellis(c)
    key = jax.random.PRNGKey(6)
    params = M.init_params(key, d, hid, t.num_edges)
    x = jax.random.normal(jax.random.PRNGKey(7), (b, d), jnp.float32)
    labels = np.array([i % c for i in range(b)])
    s = path_indicator(t, labels)
    step = jax.jit(lambda p, lr: M.sgd_train_step(t, p, x, s, lr))
    lr = jnp.float32(0.3)
    for _ in range(150):
        params, loss = step(params, lr)
    pred, _ = M.infer(t, params, x)
    acc = float(np.mean(np.asarray(pred) == labels))
    assert acc > 0.9, f"train acc {acc}, final loss {float(loss)}"
