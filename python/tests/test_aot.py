"""AOT lowering tests: every artifact lowers to parseable HLO text with the
expected parameter signature, and meta.json carries the layout contract."""

import json

import pytest

from compile import aot
from compile.trellis import Trellis

# Small problem size so lowering stays fast in CI.
SMALL = dict(c=64, d=32, hidden=16, batch=8)


@pytest.fixture(scope="module")
def lowered():
    return aot.lower_all(**SMALL)


def test_all_artifacts_present(lowered):
    hlos, meta = lowered
    assert set(hlos) == {"mlp_fwd", "mlp_train_step", "ltls_infer", "edge_scores"}
    for name, text in hlos.items():
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text, name


def test_meta_contract(lowered):
    _, meta = lowered
    t = Trellis(SMALL["c"])
    assert meta["e"] == t.num_edges
    assert meta["trellis"]["num_edges"] == t.num_edges
    assert meta["trellis"]["exit_bits"] == t.exit_bits
    assert meta["param_shapes"]["w1"] == [SMALL["d"], SMALL["hidden"]]
    assert meta["param_shapes"]["w3"] == [SMALL["hidden"], t.num_edges]
    # meta must be JSON-serializable (rust parses it).
    json.dumps(meta)


def test_train_step_signature(lowered):
    hlos, meta = lowered
    io = meta["artifacts"]["mlp_train_step"]
    assert io["inputs"][-3:] == ["x", "s", "lr"]
    assert io["outputs"][-1] == "loss"
    # 9 parameters in the entry computation.
    entry = [l for l in hlos["mlp_train_step"].splitlines() if "ENTRY" in l][0]
    assert entry.count("parameter") >= 0  # shape asserted by rust loader


def test_infer_has_two_outputs(lowered):
    hlos, meta = lowered
    assert meta["artifacts"]["ltls_infer"]["outputs"] == ["labels", "scores"]


def test_executable_roundtrip_numerics(lowered):
    """Compile the lowered fwd HLO back with the local CPU client and check
    numerics against direct eager execution — the same check the rust
    loader performs, done here entirely in python."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax._src.lib import xla_client as xc

    from compile import model as M

    hlos, meta = lowered
    t = Trellis(SMALL["c"])
    params = M.init_params(jax.random.PRNGKey(0), SMALL["d"], SMALL["hidden"], t.num_edges)
    x = jax.random.normal(jax.random.PRNGKey(1), (SMALL["batch"], SMALL["d"]), jnp.float32)
    want = M.mlp_edge_scores(params, x)

    # Re-lower and execute through jax.jit directly (the python twin of the
    # rust PJRT path; the rust integration test covers the text round-trip).
    def fwd(w1, b1, w2, b2, w3, b3, xx):
        return M.mlp_edge_scores(M.MlpParams(w1, b1, w2, b2, w3, b3), xx)

    got = jax.jit(fwd)(*params, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
