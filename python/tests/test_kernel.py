"""L1 kernel correctness: Pallas vs pure-jnp reference.

Hypothesis sweeps shapes; fixed seeds keep runs reproducible. Everything
runs in interpret mode (the CPU PJRT constraint — see kernels docstrings).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.edge_scores import edge_scores, tiled_matmul
from compile.kernels.viterbi import viterbi_decode
from compile.trellis import Trellis


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------- tiled matmul ----------

def test_matmul_exact_blocks():
    x = rand(0, 64, 256)
    w = rand(1, 256, 128)
    np.testing.assert_allclose(tiled_matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_ragged_padding():
    x = rand(2, 33, 130)
    w = rand(3, 130, 42)
    np.testing.assert_allclose(tiled_matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 70),
    d=st.integers(1, 200),
    n=st.integers(1, 150),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(b, d, n, seed):
    x = rand(seed, b, d)
    w = rand(seed + 1, d, n)
    got = tiled_matmul(x, w)
    assert got.shape == (b, n)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_matmul_nonsquare_block_sizes():
    x = rand(4, 40, 300)
    w = rand(5, 300, 50)
    got = tiled_matmul(x, w, bm=16, bk=64, bn=32)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4)


def test_edge_scores_adds_bias():
    x = rand(6, 8, 100)
    w = rand(7, 100, 42)
    b = rand(8, 42)
    np.testing.assert_allclose(
        edge_scores(x, w, b), ref.edge_scores_ref(x, w, b), rtol=1e-4, atol=1e-4
    )


def test_matmul_zero_inputs():
    x = jnp.zeros((5, 7))
    w = jnp.zeros((7, 3))
    np.testing.assert_array_equal(tiled_matmul(x, w), jnp.zeros((5, 3)))


# ---------- viterbi decode ----------

@pytest.mark.parametrize("c", [2, 3, 22, 105, 159, 255, 256, 1000])
def test_viterbi_matches_dense_oracle(c):
    t = Trellis(c)
    h = rand(c, 40, t.num_edges)
    labels, scores = viterbi_decode(h, c)
    want_l, want_s = ref.viterbi_ref(t, h)
    np.testing.assert_array_equal(labels, want_l)
    np.testing.assert_allclose(scores, want_s, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    c=st.integers(2, 400),
    b=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_viterbi_hypothesis(c, b, seed):
    t = Trellis(c)
    h = rand(seed, b, t.num_edges)
    labels, scores = viterbi_decode(h, c)
    want_l, want_s = ref.viterbi_ref(t, h)
    np.testing.assert_array_equal(labels, want_l)
    np.testing.assert_allclose(scores, want_s, rtol=1e-4, atol=1e-4)


def test_viterbi_boosted_path_wins():
    c = 105
    t = Trellis(c)
    h = np.zeros((4, t.num_edges), np.float32)
    targets = [0, 17, 63, 104]
    for row, lbl in enumerate(targets):
        for e in t.edges_of_label(lbl):
            h[row, e] = 10.0
    labels, _ = viterbi_decode(jnp.asarray(h), c)
    np.testing.assert_array_equal(labels, np.array(targets, np.int32))


def test_viterbi_large_batch_padding():
    c = 1000
    t = Trellis(c)
    h = rand(9, 300, t.num_edges)  # not a multiple of the 128 block
    labels, scores = viterbi_decode(h, c)
    assert labels.shape == (300,)
    want_l, _ = ref.viterbi_ref(t, h)
    np.testing.assert_array_equal(labels, want_l)
