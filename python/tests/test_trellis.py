"""Trellis structure tests — including the cross-language layout contract
with rust (the same invariants rust/src/graph/trellis.rs pins)."""

import numpy as np
import pytest

from compile.trellis import Trellis, floor_log2


def test_edge_count_formula():
    for c in list(range(2, 300)) + [1000, 12294, 320338]:
        t = Trellis(c)
        assert t.num_edges == 4 * floor_log2(c) + bin(c).count("1")


@pytest.mark.parametrize(
    "c,e",
    [(105, 28), (1000, 42), (12294, 56), (11947, 61), (159, 34), (3956, 52)],
)
def test_paper_table3_edge_counts(c, e):
    assert Trellis(c).num_edges == e


def test_path_count_is_c():
    for c in [2, 3, 22, 105, 256, 1000]:
        t = Trellis(c)
        # DP path count over the edge list reconstructed from labels.
        labels = {tuple(t.edges_of_label(l)) for l in range(c)}
        assert len(labels) == c  # distinct paths


def test_codec_roundtrip():
    for c in [2, 3, 22, 105, 159, 1024, 3956]:
        t = Trellis(c)
        seen = set()
        for l in range(c):
            states, exit_bit = t.path_states(l)
            if exit_bit is None:
                assert len(states) == t.steps
            else:
                assert len(states) == exit_bit + 1
                assert states[-1] == 1
            seen.add((tuple(states), exit_bit))
        assert len(seen) == c


def test_path_matrix_row_sums():
    t = Trellis(22)
    m = t.path_matrix()
    assert m.shape == (22, t.num_edges)
    sums = m.sum(axis=1)
    assert sums.max() <= t.steps + 2
    assert sums.min() >= 2


def test_figure1_c22():
    t = Trellis(22)
    assert t.steps == 4
    assert t.exit_bits == [1, 2]
    assert t.num_edges == 4 * 4 + 3


def test_exit_label_bases_partition():
    for c in [22, 105, 3956]:
        t = Trellis(c)
        nxt = 1 << t.steps
        for k, bit in enumerate(t.exit_bits):
            assert t.exit_label_base(k) == nxt
            nxt += 1 << bit
        assert nxt == c


def test_rejects_c_below_2():
    with pytest.raises(AssertionError):
        Trellis(1)


def test_fingerprint_fields():
    fp = Trellis(1000).layout_fingerprint()
    assert fp["c"] == 1000
    assert fp["num_edges"] == 42
    assert fp["steps"] == 9
    assert isinstance(fp["exit_bits"], list)
